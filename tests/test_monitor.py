"""repro.obs.monitor + repro.obs.health: windowed estimators, alert
rules (static thresholds and SLO burn-rate), the quarantine-grade
health state machine, and the serving engine's live responses.

The acceptance bar for the engine tests: a soak with a mid-stream
fault burst must yield — from the exported ``obs_events.jsonl``
ALONE — the firing alert, the health transition, the engine's
response action, and the recovery, all replayable via ``replay()``
into a registry that matches the live counters exactly."""
import json

import pytest

from repro.configs import reduce_cfg
from repro.configs.registry import get_arch
from repro.obs import (AlertRule, EventBus, FaultEvent, HealthPolicy,
                       HealthTracker, Monitor, Observability, replay,
                       validate_event)
from repro.obs.monitor import health_scope, wilson_interval
from repro.protect import ProtectionPlan
from repro.serving import (FaultInjection, ServingEngine, TenantSpec,
                           chat_stream)

#: registry families the counter-mirror invariant covers — replaying
#: the event stream must reproduce these lines exactly
MIRRORED = ("repro_detections_total", "repro_injections_total",
            "repro_abft_checks_total", "repro_abft_errors_total",
            "repro_alerts_total", "repro_health_transitions_total",
            "repro_health_state", "repro_health_actions_total",
            "repro_escapes_total", "repro_false_positives_total",
            "repro_paging_ops_total")


def _mirrored_lines(registry):
    return sorted(l for l in registry.to_prometheus().splitlines()
                  if l.startswith(MIRRORED))


# ------------------------------ primitives ----------------------------------

def test_wilson_interval_bounds_and_monotonicity():
    assert wilson_interval(0, 0) == (0.0, 1.0)
    lo, hi = wilson_interval(0, 50)
    assert lo == 0.0 and 0.0 < hi < 0.15      # upper bound shrinks w/ n
    lo2, hi2 = wilson_interval(0, 500)
    assert hi2 < hi
    lo, hi = wilson_interval(8, 40)
    assert 0.0 < lo < 0.2 < hi < 1.0
    # the interval always contains the point estimate
    for k, n in ((1, 3), (5, 7), (99, 100)):
        lo, hi = wilson_interval(k, n)
        assert lo <= k / n <= hi


def test_alert_rule_validation():
    with pytest.raises(ValueError, match="metric"):
        AlertRule(name="x", metric="nope", threshold=1)
    with pytest.raises(ValueError, match="cmp"):
        AlertRule(name="x", metric="detections", threshold=1, cmp="!!")
    with pytest.raises(ValueError, match="severity"):
        AlertRule(name="x", metric="detections", threshold=1,
                  severity="explode")
    with pytest.raises(ValueError, match="window"):
        AlertRule(name="x", metric="detections", threshold=1,
                  window_ticks=0, window_s=0.0)


def test_health_scope_rollup_order():
    assert health_scope("qgemm", "prem", "c0") == "tenant:prem"
    assert health_scope("qgemm", "", "c0") == "cell:c0"
    assert health_scope("qgemm", "", "") == "op:qgemm"


def test_health_tracker_hysteresis_probes_and_recovery():
    pol = HealthPolicy(degrade_after=2, quarantine_after=3,
                       recover_after=2, probe_every=3)
    tr = HealthTracker("tenant:a", pol)
    assert tr.update(True, 1.0) is None              # streak 1 < 2
    t = tr.update(True, 2.0, reason="burst")
    assert (t.old, t.new, t.reason) == ("healthy", "degraded", "burst")
    # degraded: needs quarantine_after consecutive alerting ticks
    assert tr.update(True, 3.0) is None
    assert tr.update(False, 4.0) is None             # streak resets
    assert tr.update(True, 5.0) is None
    assert tr.update(True, 6.0) is None
    t = tr.update(True, 7.0)
    assert t.new == "quarantined"
    # quarantine-grade severity jumps straight there from healthy
    fast = HealthTracker("tenant:b", pol)
    fast.update(True, 1.0, quarantine_grade=True)
    t = fast.update(True, 2.0, quarantine_grade=True)
    assert (t.old, t.new) == ("healthy", "quarantined")
    # probes: one admission per probe_every ticks, and the first one
    # earns its wait
    assert not tr.take_probe()
    tr.update(False, 8.0)
    tr.update(False, 9.0)                            # also recovers ↓
    assert tr.state == "degraded"                    # 2 clean ticks
    tr2 = HealthTracker("tenant:c", pol)
    tr2.update(True, 1.0, quarantine_grade=True)     # streak 1 < 2
    assert tr2.update(True, 2.0, quarantine_grade=True).new == \
        "quarantined"                                # tick 2, probe@2
    for k in range(9):
        allowed = tr2.take_probe()                   # at tick 2 + k
        assert allowed == (k in (3, 6)), k           # every 3rd tick
        tr2.update(True, 3.0 + k, quarantine_grade=True)
    # full recovery steps down one state per quiet period
    assert tr.update(False, 10.0) is None
    t = tr.update(False, 11.0)
    assert (t.old, t.new, t.reason) == ("degraded", "healthy",
                                        "recovered")
    assert tr.take_probe()                           # healthy: always


# ------------------------------ windows + rules -----------------------------

def test_detection_rule_fires_then_ages_out_over_idle_ticks():
    mon = Monitor(
        rules=[AlertRule(name="burst", metric="detections", threshold=3,
                         window_ticks=4)],
        health=HealthPolicy(degrade_after=1, quarantine_after=3,
                            recover_after=2, probe_every=2))
    t = 0.0
    for _ in range(3):
        t += 1.0
        mon.record_step(t, {"qgemm": (2, 0)}, tenants=("a",))
    assert not mon.active_alerts()
    for _ in range(3):
        t += 1.0
        mon.record_step(t, {"qgemm": (2, 1)}, tenants=("a",))
    assert [a.rule for a in mon.active_alerts()] == ["burst"]
    assert mon.tenant_state("a") == "degraded"
    assert mon.admission_allowed("a")                # degraded != gated
    # idle ticks age the flagged samples out of the 4-tick window: the
    # alert resolves and health recovers WITHOUT new traffic (the
    # quarantined-lane deadlock this tick-indexing prevents)
    for _ in range(10):
        t += 0.001
        mon.idle_tick(t)
    assert not mon.active_alerts()
    assert mon.tenant_state("a") == "healthy"
    s = mon.summary()
    assert s["alerts_fired"] == 1
    assert s["alerts"][0]["resolved_t_s"] is not None
    assert [(x["old"], x["new"]) for x in s["transitions"]] == \
        [("healthy", "degraded"), ("degraded", "healthy")]


def test_fp_rate_proxy_injection_suppression_and_min_checks():
    rule = AlertRule(name="fp", metric="fp_rate_low", threshold=0.02,
                     cmp=">", window_ticks=8, min_checks=20)
    # flags with no known injection in-window are presumed false
    mon = Monitor(rules=[rule])
    t = 0.0
    for _ in range(8):
        t += 1.0
        mon.record_step(t, {"qgemm": (5, 2)}, tenants=("a",))
    assert [a.rule for a in mon.active_alerts()] == ["fp"]
    # identical traffic with an injection event in-window: the flags
    # are explained, fp proxy is 0, no alert
    mon2 = Monitor(rules=[rule])
    obs = Observability.create()
    mon2.bind(obs)
    obs.bus.emit(FaultEvent(op="qgemm", step=0, source="t",
                            kind="injection", t_s=0.5))
    for i in range(8):
        obs.bus.emit(FaultEvent(
            op="step", step=i, source="t", kind="info", t_s=1.0 + i,
            attrs={"channel": "step", "by_op": {"qgemm": [5, 2]},
                   "tenants": ["a"]}))
    assert not mon2.active_alerts()
    # below min_checks the estimator abstains entirely
    mon3 = Monitor(rules=[rule])
    t = 0.0
    for _ in range(8):
        t += 1.0
        mon3.record_step(t, {"qgemm": (2, 1)}, tenants=("a",))
    assert not mon3.active_alerts()                  # 16 checks < 20


def test_burn_rate_rule_needs_short_and_long_window():
    rule = AlertRule(name="burn", metric="detections", threshold=2,
                     window_ticks=2, long_window_ticks=8,
                     long_threshold=4)
    mon = Monitor(rules=[rule])
    t = 0.0
    for _ in range(2):
        t += 1.0
        mon.record_step(t, {"q": (1, 1)})
    # short window fires (2 >= 2) but the long budget isn't burned yet
    assert not mon.active_alerts()
    for _ in range(2):
        t += 1.0
        mon.record_step(t, {"q": (1, 1)})
    assert [a.rule for a in mon.active_alerts()] == ["burn"]
    assert mon.state("op:q") == "degraded"


def test_latency_p99_rule_over_step_durations():
    rule = AlertRule(name="slow", metric="latency_p99_ms",
                     threshold=100.0, window_ticks=4, min_samples=3,
                     op="step/serve", severity="warn")
    mon = Monitor(rules=[rule])
    t = 0.0
    for ms in (5.0, 5.0, 5.0, 5.0):
        t += 1.0
        mon.record_step(t, {}, tenants=("a",), duration_ms=ms,
                        kind="serve")
    assert not mon.active_alerts()
    for ms in (250.0, 250.0, 250.0):
        t += 1.0
        mon.record_step(t, {}, tenants=("a",), duration_ms=ms,
                        kind="serve")
    (f,) = mon.active_alerts()
    assert f.rule == "slow" and f.value >= 250.0
    # warn severity never degrades health
    assert mon.tenant_state("a") == "healthy"


def test_cell_events_fold_into_cell_scopes_and_replay():
    mon = Monitor(rules=[AlertRule(name="cellburst", metric="detections",
                                   threshold=5, window_ticks=4)])
    obs = Observability.create()
    mon.bind(obs)
    # the live incs the soak publisher pairs with its cell event
    obs.registry.counter("repro_detections_total").inc(6, cell="c1")
    obs.registry.counter("repro_injections_total").inc(8, cell="c1")
    obs.registry.counter("repro_escapes_total").inc(1, cell="c1")
    obs.registry.counter("repro_false_positives_total").inc(0, cell="c1")
    obs.bus.emit(FaultEvent(
        op="soak", step=0, source="serving.soak", kind="cell",
        cell_id="c1", errors=7, checks=8, t_s=1.0,
        attrs={"effective_detected": 6, "escapes": 1,
               "false_positives": 0}))
    assert mon.state("cell:c1") == "degraded"
    (f,) = mon.active_alerts()
    assert f.scope == "cell:c1" and f.value == 6.0
    # satellite: replay folds cell events into the {cell=...} counters
    reg = replay(obs.bus)
    assert reg.counter("repro_detections_total").value(cell="c1") == 6
    assert reg.counter("repro_injections_total").value(cell="c1") == 8
    assert reg.counter("repro_escapes_total").value(cell="c1") == 1
    assert reg.counter("repro_false_positives_total").value(cell="c1") \
        == 0
    # alert + health events from the monitor replay into their counters
    assert reg.counter("repro_alerts_total").value(
        rule="cellburst", scope="cell:c1", severity="degrade") == 1
    assert reg.counter("repro_health_transitions_total").value(
        scope="cell:c1", to="degraded") == 1
    assert _mirrored_lines(obs.registry) == _mirrored_lines(reg)


def test_monitor_estimate_sensor():
    mon = Monitor()
    t = 0.0
    for _ in range(5):
        t += 1.0
        mon.record_step(t, {"qgemm": (4, 1), "kv_cache": (2, 0)},
                        tenants=("a",))
    est = mon.estimate(op="qgemm")
    assert est["errors"] == 5 and est["checks"] == 20
    assert est["flag_rate"] == pytest.approx(0.25)
    assert est["flag_rate_low"] < 0.25 < est["flag_rate_high"]
    everything = mon.estimate()
    assert everything["checks"] == 30


def test_monitor_ignores_own_emissions_no_recursion():
    mon = Monitor(rules=[AlertRule(name="b", metric="detections",
                                   threshold=1, window_ticks=4)])
    obs = Observability.create()
    mon.bind(obs)
    for i in range(4):
        obs.bus.emit(FaultEvent(
            op="step", step=i, source="t", kind="info", t_s=1.0 + i,
            attrs={"channel": "step", "by_op": {"q": [1, 1]},
                   "tenants": ["a"]}))
    # the bus now holds the monitor's own alert/health events; feeding
    # the same bus to a fresh monitor must not loop or double-count
    alerts = [e for e in obs.bus if e.kind == "alert"]
    health = [e for e in obs.bus if e.kind == "health"]
    assert alerts and health
    for ev in obs.bus:
        validate_event(ev.to_dict())
    assert mon.summary()["ticks"] == 4


# --------------------------- train-loop publishing --------------------------

def test_train_loop_publishes_into_monitor(tmp_path):
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.loop import LoopConfig, TrainLoop

    calls = {}

    def step_fn(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch["x"].mean())
        faulty = int(state["step"]) == 3 and calls.setdefault("f", 0) == 0
        if faulty:
            calls["f"] = 1
        m = {"abft/gemm_errors": jnp.asarray(int(faulty), jnp.int32),
             "loss": jnp.mean((w - batch["x"].mean()) ** 2)}
        return {"w": w, "step": state["step"] + 1}, m

    class DS:
        def batch_at(self, step):
            rng = np.random.default_rng(step)
            return {"x": jnp.asarray(rng.standard_normal(8),
                                     jnp.float32)}

    mon = Monitor()          # auto-creates + binds an obs bundle
    cfg = LoopConfig(ckpt_dir=str(tmp_path / "ck"), save_every=100,
                     fault_policy="recompute", log_every=100)
    loop = TrainLoop(step_fn, DS(), cfg=cfg, monitor=mon)
    state0 = {"w": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}
    loop.run(state0, 6)
    assert mon.summary()["ticks"] == 6               # one per step
    assert mon.estimate(op="gemm")["errors"] == 1
    # the single flagged step is under the default burst threshold
    assert not mon.active_alerts()
    assert mon.summary()["health"] == {}


# ------------------------- serving engine integration -----------------------

N_SLOTS = 2
MAX_PROMPT = 8
MAX_NEW = 4


@pytest.fixture(scope="module")
def engine():
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    tenants = [TenantSpec("t", ProtectionPlan.parse("*:policy=log",
                                                    name="t"))]
    eng = ServingEngine(cfg, tenants, n_slots=N_SLOTS,
                        max_prompt=MAX_PROMPT, max_new_tokens=MAX_NEW,
                        seed=0)
    eng.warmup()
    return eng


def _stream(n, seed=0):
    return chat_stream(n, tenants={"t": 1.0}, rate_rps=500.0, seed=seed,
                       mean_prompt=6, max_prompt=MAX_PROMPT,
                       mean_output=3, max_output=MAX_NEW)


def test_engine_burst_to_quarantine_to_recovery_from_jsonl(engine,
                                                           tmp_path):
    """The acceptance scenario: a mid-stream fault burst drives alert →
    degraded/quarantined → engine responses → probe recovery, and every
    link of that chain is reconstructible from obs_events.jsonl alone,
    with exact replay counter-equivalence."""
    engine.reset_state()
    obs = Observability.create()
    mon = Monitor()
    burst = [FaultInjection(step=s, victim="mlp.down", seed=i)
             for i, s in enumerate((4, 5, 6))]
    tel = engine.run(_stream(24, seed=3), inject=burst, obs=obs,
                     monitor=mon)
    s = tel.summary()

    # live side: the burst fired the detection rules and the machine
    # walked up to quarantined and back down to healthy
    fired = {a["rule"] for a in s["monitor"]["alerts"]}
    assert "detection-burst" in fired
    assert all(a["resolved_t_s"] is not None
               for a in s["monitor"]["alerts"])
    hops = [(x["old"], x["new"]) for x in s["monitor"]["transitions"]]
    assert hops[0][0] == "healthy"                   # escalated up...
    assert any(new == "quarantined" for _, new in hops)
    assert s["monitor"]["health"] == {"tenant:t": "healthy"}
    # every completed request still finished (quarantine gates
    # admission, it does not drop queued work)
    assert sum(t["completed"] for t in s["per_tenant"].values()) == 24

    # export, then forget the live objects: the JSONL alone must carry
    # the whole story
    paths = obs.write(str(tmp_path))
    events = [json.loads(l) for l in open(paths["events"])]
    for d in events:
        validate_event(d)
    firing = [d for d in events if d["kind"] == "alert"
              and d["attrs"]["state"] == "firing"]
    assert any(d["attrs"]["rule"] == "detection-burst" for d in firing)
    trans = [d for d in events if d["kind"] == "health"
             and d["source"] == "obs.monitor"]
    seq = [(d["attrs"]["from"], d["attrs"]["to"]) for d in trans]
    assert seq[0][0] == "healthy"
    assert any(new == "quarantined" for _, new in seq)
    assert seq[-1][1] == "healthy"                   # recovery is there
    actions = [d["attrs"]["action"] for d in events
               if d["kind"] == "health"
               and d["source"] == "serving.engine"]
    assert "escalate" in actions and "quarantine" in actions
    assert "recover" in actions

    # exact counter-mirror: replaying the JSONL reproduces the live
    # registry's fault-pipeline families line-for-line
    reg = replay(paths["events"])
    assert _mirrored_lines(obs.registry) == _mirrored_lines(reg)


def test_engine_monitor_responses_can_be_disabled(engine):
    from repro.obs import EngineResponses

    engine.reset_state()
    mon = Monitor(responses=EngineResponses(quarantine=False,
                                            escalate=False, scrub=False))
    obs = Observability.create()
    burst = [FaultInjection(step=s, victim="mlp.down", seed=i)
             for i, s in enumerate((4, 5, 6))]
    tel = engine.run(_stream(24, seed=3), inject=burst, obs=obs,
                     monitor=mon)
    actions = {e.attrs.get("action") for e in obs.bus
               if e.kind == "health" and e.source == "serving.engine"}
    assert "quarantine" not in actions and "escalate" not in actions
    # observation still happened — only the responses were held back
    assert tel.summary()["monitor"]["alerts_fired"] >= 1


def test_engine_paged_paging_lifecycle_events_and_replay(tmp_path):
    """Satellite: the paged-KV lifecycle (admit / evict_corrupt /
    rebuild / scrub_cache) emits typed info events + tracer spans, and
    replay mirrors repro_paging_ops_total exactly."""
    from repro.paging import PagingConfig
    from repro.serving.workload import chat_stream as paged_stream

    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    plan = ProtectionPlan.parse("*:policy=recompute,kv_cache_paged:on",
                                name="paged-fix")
    eng = ServingEngine(cfg, [TenantSpec("a", plan)], n_slots=2,
                        max_prompt=32, max_new_tokens=8,
                        paging=PagingConfig(page_size=8, n_pages=32))
    obs = Observability.create()
    stream = paged_stream(6, tenants={"a": 1.0}, rate_rps=200.0, seed=3,
                          mean_prompt=24, max_prompt=32, mean_output=6,
                          max_output=8, prefix_len=16, prefix_seed=77)
    tel = eng.run(stream, inject=[FaultInjection(
        step=5, target="kv", persistent=True, seed=7)], obs=obs)
    assert tel.summary()["faults"]["injections_detected"] == 1

    paging = [e for e in obs.bus if e.kind == "info"
              and e.attrs.get("channel") == "paging"]
    actions = [e.attrs["action"] for e in paging]
    for want in ("admit", "scrub_cache", "evict_corrupt", "rebuild"):
        assert want in actions, actions
    admit = next(e for e in paging if e.attrs["action"] == "admit")
    assert admit.attrs["pages"] >= 1 and admit.attrs["lane"]
    assert admit.request_ids                         # attribution rides
    span_names = {s.name for s in obs.tracer.spans}
    assert {"paged_admit", "paged_scrub_cache",
            "paged_rebuild"} <= span_names
    # counters match the event stream, live and replayed
    ops = obs.registry.counter("repro_paging_ops_total")
    for action in set(actions):
        n = sum(1 for a in actions if a == action)
        assert sum(ops.value(action=action, lane=lane)
                   for lane in {e.attrs["lane"] for e in paging}) == n
    paths = obs.write(str(tmp_path))
    reg = replay(paths["events"])
    assert _mirrored_lines(obs.registry) == _mirrored_lines(reg)
