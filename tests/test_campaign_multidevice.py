"""Mesh-sharded campaign execution: the ``CampaignSpec.mesh`` axis,
executor mesh-slice placement + device-count fallback, and the
multidevice grid whose soak cells run ``checked_psum`` through a real
shard_map collective (subprocess — the tier-1 host has one device).
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import pytest

from repro.campaign import (CampaignSpec, expand, get_target,
                            latency_markdown, resolve_device_count,
                            run_cell)
from repro.campaign.executor import _cell_mesh
from repro.campaign.grids import multidevice_specs
from repro.campaign.spec import CellPlan, cell_seed


def _plan(target="train_payload_shard", dtype="int8", shards=4, steps=2,
          samples=2):
    cid = f"mdtest/{target}/{dtype}/{shards}"
    return CellPlan(
        cell_id=cid, target=target, fault_model="bitflip",
        bit_band="significant", shape=(2, 8), dtype=dtype,
        samples=samples, clean_samples=1, flips=1,
        seed=cell_seed(0, cid), measure_overhead=False, steps=steps,
        data_shards=shards)


# ---------------------------------------------------------------------------
# spec expansion: the mesh axis
# ---------------------------------------------------------------------------

def test_mesh_sweep_gated_on_shardable_targets():
    spec = CampaignSpec(
        name="t", targets=("gemm_packed", "train_payload"),
        bit_bands=("significant",), dtypes=("int8",),
        samples=2, steps=2, mesh=(1, 4))
    plans, skipped = expand(spec)
    by_target = {}
    for p in plans:
        by_target.setdefault(p.target, []).append(p)
    # shardable target: both shard counts, suffix only when sharded
    tp = sorted(p.data_shards for p in by_target["train_payload"])
    assert tp == [1, 4]
    assert any(p.cell_id.endswith("/shards4")
               for p in by_target["train_payload"])
    assert not any("/shards" in p.cell_id and p.data_shards == 1
                   for p in plans)
    # single-device target: one cell, sweep logged
    assert [p.data_shards for p in by_target["gemm_packed"]] == [1]
    assert any("cannot shard its collective" in s["reason"]
               for s in skipped)


def test_mesh_values_validated():
    with pytest.raises(ValueError):
        CampaignSpec(name="t", targets=("train_payload",), mesh=(0,))


def test_multidevice_grid_expands_with_sharded_and_contrast_cells():
    all_plans = []
    for s in multidevice_specs(seed=0, quick=True):
        plans, _ = expand(s)
        all_plans += plans
    targets = {p.target for p in all_plans}
    assert {"train_payload_shard", "train_reduced",
            "train_payload"} <= targets
    shard_counts = {(p.target, p.data_shards) for p in all_plans}
    # the contrast pair: same seam with and without a real collective
    assert ("train_payload", 1) in shard_counts
    assert ("train_payload", 4) in shard_counts
    assert all(p.data_shards == 4 for p in all_plans
               if p.target in ("train_payload_shard", "train_reduced"))


def test_new_seam_targets_registered_with_bounds():
    ps = get_target("train_payload_shard")
    assert ps.shardable and ps.soak is not None
    assert ps.analytic_bound(_plan("train_payload_shard")) == 1.0
    rd = get_target("train_reduced")
    assert rd.shardable
    assert rd.analytic_bound(
        _plan("train_reduced", dtype="int32")) == 0.0


# ---------------------------------------------------------------------------
# executor: device-count validation + mesh-slice placement fallback
# ---------------------------------------------------------------------------

def test_resolve_device_count_falls_back_with_warning():
    import jax
    avail = jax.local_device_count()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert resolve_device_count(avail + 7) == avail
    assert any("falling" in str(x.message) for x in w)
    # in-range requests are trusted; None means "all"
    assert resolve_device_count(None) == avail
    assert resolve_device_count(1) == 1


def test_cell_mesh_clamps_to_available_devices():
    import jax
    if jax.local_device_count() > 1:
        pytest.skip("needs a single-device host to exercise the clamp")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh, shards = _cell_mesh(_plan(shards=4))
    assert mesh is None and shards == 1
    assert any("data_shards" in str(x.message) for x in w)
    # unsharded plans never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert _cell_mesh(_plan(shards=1)) == (None, 1)


@pytest.mark.slow
def test_sharded_plan_degrades_to_single_device_cell():
    """data_shards=4 on a 1-device host must still produce a valid cell
    (the payload seam degenerates to the single-device verify) with the
    degradation recorded, not a Mesh/pmap shape error."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        r = run_cell(_plan(shards=4, samples=2), chunk=4)
    m = r.metrics
    assert m.shards == 1 and m.collective_verified is False
    assert m.raw_detection_rate == 1.0      # bound holds even degraded
    assert m.shard_detections is None


# ---------------------------------------------------------------------------
# end to end: a sharded soak cell on a real 4-device mesh (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_cell_end_to_end_four_device_subprocess():
    """The acceptance cell: a training-soak cell with data_shards=4 runs
    checked_psum through a REAL shard_map psum, detects a single-shard
    int8 payload flip after the collective with latency 0 recorded in
    the soak histogram, and attributes the corruption to shard 0."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        from repro.campaign import run_cell
        from repro.campaign.spec import CellPlan, cell_seed

        cid = "e2e/train_payload_shard"
        plan = CellPlan(
            cell_id=cid, target="train_payload_shard",
            fault_model="bitflip", bit_band="significant", shape=(2, 8),
            dtype="int8", samples=2, clean_samples=1, flips=1,
            seed=cell_seed(0, cid), measure_overhead=False, steps=2,
            data_shards=4)
        m = run_cell(plan, chunk=4).metrics
        print("METRICS=" + json.dumps(m.to_dict()))
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("METRICS=")]
    assert line, (r.stdout[-2000:], r.stderr[-2000:])
    m = json.loads(line[0][len("METRICS="):])
    assert m["shards"] == 4 and m["collective_verified"] is True
    assert m["raw_detection_rate"] == 1.0
    assert m["escapes"] == 0 and m["false_positives"] == 0
    assert m["detection_latency_hist"] == [2, 0]    # caught in-step
    assert m["mean_detection_latency"] == 0.0
    assert m["shard_detections"] == [2, 0, 0, 0]    # blames shard 0


# ---------------------------------------------------------------------------
# artifact rendering: the shards column
# ---------------------------------------------------------------------------

def test_latency_markdown_renders_shards_column():
    result = {
        "campaign": "t",
        "cells": [{
            "cell_id": "train_payload_shard/x/steps2/shards4",
            "plan": {},
            "metrics": {
                "steps": 2, "detection_latency_hist": [2, 0],
                "mean_detection_latency": 0.0, "divergence_mean": 1e-5,
                "divergence_max": 2e-5, "loss_divergence_mean": 1e-4,
                "shards": 4, "collective_verified": True,
                "shard_detections": [2, 0, 0, 0]},
        }, {
            "cell_id": "train_payload/x/steps2",
            "plan": {},
            "metrics": {
                "steps": 2, "detection_latency_hist": [2, 0],
                "mean_detection_latency": 0.0, "divergence_mean": 0.0,
                "divergence_max": 0.0, "loss_divergence_mean": 0.0,
                "shards": 1, "collective_verified": False,
                "shard_detections": None},
        }],
    }
    md = latency_markdown(result)
    assert "| shards |" in md.splitlines()[2]
    assert "4✓ [2 0 0 0]" in md
    assert "| 1 |" in md
