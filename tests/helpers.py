"""Shared test utilities: reduced-config re-exports + optional hypothesis.

``reduce_cfg`` / ``small_arch`` live in :mod:`repro.configs.reduce` (runtime
entry points use them too); they are re-exported here for the test modules.

``given`` / ``settings`` / ``st`` come from hypothesis when it is installed.
When it is not (the bare CI container), a tiny deterministic shim runs each
property test over ``max_examples`` seeded random draws — weaker than real
hypothesis (no shrinking, no database) but the properties still execute
instead of the whole module failing at collection.
"""
from repro.configs.reduce import reduce_cfg, small_arch  # noqa: F401

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: rng.choice(elems))

    st = _Strategies()

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples", 20)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    fn(*[s.draw(rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._shim_max_examples = getattr(
                fn, "_shim_max_examples", 20)
            return wrapper
        return deco
