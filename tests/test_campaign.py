"""Resilience-campaign subsystem: spec→plan expansion, metrics math,
artifact round-trip, and a small end-to-end cell per target family."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.campaign import (CampaignSpec, CellMetrics, TARGETS, cell_seed,
                            compute_metrics, expand, find_cells,
                            load_artifact, markdown_table, run_campaign,
                            run_cell, wilson_interval)
from repro.campaign.spec import DLRM_GEMM_SHAPES


# ----------------------------- spec -> plans --------------------------------

def test_expand_cartesian_product_and_seeds():
    spec = CampaignSpec(
        name="t", targets=("gemm_packed",),
        fault_models=("bitflip", "random_value"),
        bit_bands=("all", "significant"),
        shapes=((4, 64, 128), (8, 64, 128)),
        samples=10, seed=3)
    plans, skipped = expand(spec)
    # random_value has no bands -> (bitflip × 2 bands + random_value × all)
    # × 2 shapes
    assert len(plans) == 6
    assert len({p.cell_id for p in plans}) == 6
    assert len(skipped) == 2        # random_value × significant × 2 shapes
    for p in plans:
        assert p.seed == cell_seed(3, p.cell_id)
    # stable across re-expansion
    plans2, _ = expand(spec)
    assert [p.cell_id for p in plans] == [p2.cell_id for p2 in plans2]
    assert [p.seed for p in plans] == [p2.seed for p2 in plans2]


def test_expand_skips_wrong_arity_and_dtype():
    spec = CampaignSpec(
        name="t", targets=("gemm_packed", "embedding_bag"),
        shapes=((4, 64, 128),),          # gemm arity only
        dtypes=("int8", "int32"),
        samples=5)
    plans, skipped = expand(spec)
    assert [p.target for p in plans] == ["gemm_packed"]
    reasons = " | ".join(s["reason"] for s in skipped)
    assert "arity" in reasons and "dtype" in reasons


def test_expand_default_shapes_and_clean_samples():
    spec = CampaignSpec(name="t", targets=("kv_cache",), samples=7)
    plans, _ = expand(spec)
    assert plans[0].shape == TARGETS["kv_cache"].default_shapes[0]
    assert plans[0].clean_samples == 7        # None -> samples
    spec2 = CampaignSpec(name="t", targets=("kv_cache",), samples=7,
                         clean_samples=0)
    assert expand(spec2)[0][0].clean_samples == 0


def test_expand_skips_band_undefined_for_dtype():
    # kv_cache supports the exponent band (float32 scales) but int8 has
    # no exponent bits — the int8 × exponent cell must skip, not crash
    spec = CampaignSpec(name="t", targets=("kv_cache",),
                        bit_bands=("all", "exponent"),
                        dtypes=("int8", "float32"), samples=5)
    plans, skipped = expand(spec)
    ids = {p.cell_id for p in plans}
    assert any("/exponent/" in i and "float32" in i for i in ids)
    assert not any("/exponent/" in i and "int8" in i for i in ids)
    assert any("undefined for dtype int8" in s["reason"] for s in skipped)


def test_expand_skips_multi_flip_for_single_element_targets():
    spec = CampaignSpec(name="t", targets=("embedding_bag",),
                        samples=5, flips_per_trial=2)
    plans, skipped = expand(spec)
    assert plans == []
    assert any("single element" in s["reason"] for s in skipped)


def test_full_grid_expands_clean():
    from repro.campaign.grids import GRIDS
    for name, build in GRIDS.items():
        for spec in build(seed=0):
            expand(spec)       # no KeyError/ValueError on any shipped grid


def test_expand_unknown_target_raises():
    with pytest.raises(KeyError, match="unknown target"):
        expand(CampaignSpec(name="t", targets=("nope",), samples=1))


def test_spec_validation():
    with pytest.raises(ValueError):
        CampaignSpec(name="t", targets=("gemm_packed",), samples=0)
    with pytest.raises(ValueError):
        CampaignSpec(name="t", targets=("gemm_packed",), samples=1,
                     flips_per_trial=0)
    with pytest.raises(ValueError):
        CampaignSpec(name="t", targets=("gemm_packed",), samples=1,
                     steps=0)


def test_spec_steps_persistent_round_trip():
    spec = CampaignSpec(name="t", targets=("train_moments",),
                        dtypes=("float32",), samples=2, steps=5,
                        persistent=[False, True])      # list from JSON
    assert spec.persistent == (False, True)            # coerced to tuple
    assert CampaignSpec.from_dict(spec.to_dict()) == spec
    plans, _ = expand(spec)
    assert all(p.steps == 5 for p in plans)
    for p in plans:
        from repro.campaign.spec import CellPlan
        assert CellPlan(**p.to_dict()) == p            # plan round-trips


def test_expand_drops_persistent_duplicate_at_one_step():
    # at steps=1 a "re-strike every step" fault IS the transient fault —
    # the would-be /persistent cell is a duplicate and must be dropped
    spec = CampaignSpec(name="t", targets=("train_moments",),
                        dtypes=("float32",), samples=2,
                        persistent=(False, True))      # default steps=1
    plans, skipped = expand(spec)
    assert [p.persistent for p in plans] == [False]
    assert any("indistinguishable from transient" in s["reason"]
               for s in skipped)


def test_dlrm_shape_set_is_paper_sized():
    assert len(DLRM_GEMM_SHAPES) == 28
    assert (1, 800, 3200) in DLRM_GEMM_SHAPES


# ------------------------------- metrics ------------------------------------

def test_metrics_math():
    m = compute_metrics(samples=100, detected=90, corrupted=95,
                        detected_and_corrupted=88, clean_samples=50,
                        false_positives=2)
    # escapes: corrupted but undetected
    assert m.escapes == 95 - 88 == 7
    # effective: everything except escapes (masked counts as handled)
    assert m.effective_detected == 93
    assert m.detection_rate == pytest.approx(0.93)
    assert m.raw_detection_rate == pytest.approx(0.90)
    assert m.escape_rate == pytest.approx(0.07)
    assert m.fp_rate == pytest.approx(0.04)
    lo, hi = m.ci95
    assert lo < 0.93 < hi


def test_metrics_overhead_ratio():
    m = compute_metrics(samples=1, detected=1, corrupted=1,
                        detected_and_corrupted=1, clean_samples=0,
                        false_positives=0, protected_s=1.2,
                        unprotected_s=1.0)
    assert m.overhead == pytest.approx(0.2)
    assert m.fp_rate == 0.0


def test_wilson_interval_basics():
    lo, hi = wilson_interval(0, 0)
    assert (lo, hi) == (0.0, 1.0)
    lo, hi = wilson_interval(100, 100)
    assert hi == pytest.approx(1.0) and lo > 0.95
    lo50, hi50 = wilson_interval(50, 100)
    assert lo50 < 0.5 < hi50


# ------------------------- end-to-end + artifacts ---------------------------

def _tiny_specs():
    return [
        CampaignSpec(name="t-gemm", targets=("gemm_packed",),
                     shapes=((4, 32, 64),), samples=64, seed=5),
        CampaignSpec(name="t-kv", targets=("kv_cache",),
                     shapes=((1, 1, 32, 32),), dtypes=("int8",),
                     samples=32, seed=5),
    ]


def test_run_campaign_end_to_end_and_roundtrip(tmp_path):
    result = run_campaign("unit", _tiny_specs(), out_dir=str(tmp_path))

    gemm = find_cells(result, target="gemm_packed")[0]
    m = CellMetrics.from_dict(gemm["metrics"])
    # m=4: analytic bound 1-(3/256)^4 ~ 0.99999998
    assert m.detection_rate > 0.95
    assert m.fp_rate == 0.0
    assert m.analytic_bound == pytest.approx(1.0, abs=1e-6)

    kvc = find_cells(result, target="kv_cache")[0]
    mk = CellMetrics.from_dict(kvc["metrics"])
    assert mk.detection_rate == 1.0 and mk.escapes == 0

    # JSON artifact round-trip
    path = tmp_path / "BENCH_campaign_unit.json"
    assert path.exists()
    loaded = load_artifact(str(path))
    assert loaded == json.loads(json.dumps(result))  # JSON-clean
    assert [c["cell_id"] for c in loaded["cells"]] \
        == [c["cell_id"] for c in result["cells"]]
    for orig, back in zip(result["cells"], loaded["cells"]):
        assert CellMetrics.from_dict(back["metrics"]) \
            == CellMetrics.from_dict(orig["metrics"])
    assert CampaignSpec.from_dict(loaded["specs"][0]) == _tiny_specs()[0]

    md = markdown_table(loaded)
    assert "gemm_packed/bitflip" in md and "| cell |" in md
    assert (tmp_path / "BENCH_campaign_unit.md").exists()


def test_run_cell_deterministic_for_fixed_seed():
    spec = CampaignSpec(name="t", targets=("gemm_packed",),
                        shapes=((2, 32, 64),), samples=40, seed=9)
    plan = expand(spec)[0][0]
    m1 = run_cell(plan).metrics
    m2 = run_cell(plan).metrics
    assert m1 == m2


def test_eb_cell_significant_band():
    spec = CampaignSpec(name="t", targets=("embedding_bag",),
                        bit_bands=("significant",),
                        shapes=((2_000, 64, 4, 20),), samples=60, seed=2)
    plan = expand(spec)[0][0]
    m = run_cell(plan, chunk=30).metrics
    assert m.detection_rate >= 0.95
    assert m.fp_rate <= 0.1


def test_decode_soak_multi_step_histogram_and_persistence():
    """decode_step on the soak protocol: a steps-deep cell carries the
    per-step detection-latency histogram, and the persistent variant is
    its own cell id."""
    spec = CampaignSpec(name="t", targets=("decode_step",),
                        fault_models=("bitflip",),
                        bit_bands=("significant",),
                        samples=4, clean_samples=2, seed=0,
                        steps=3, persistent=(False, True))
    plans, _ = expand(spec)
    assert len(plans) == 2
    ids = {p.cell_id for p in plans}
    assert any(i.endswith("/steps3") for i in ids)
    assert any(i.endswith("/steps3/persistent") for i in ids)
    for plan in plans:
        m = run_cell(plan).metrics
        assert m.steps == 3
        assert len(m.detection_latency_hist) == 3
        assert sum(m.detection_latency_hist) <= m.samples
        assert m.detected >= 1          # significant-band weight flip
        if m.detection_latency_hist[0] == m.detected:
            assert m.mean_detection_latency == 0.0


def test_decode_soak_steps1_keeps_baseline_cell_id():
    """The quick grid's decode cell must keep its pre-migration id (no
    /stepsN suffix) so committed baselines and seeds stay comparable."""
    spec = CampaignSpec(name="t", targets=("decode_step",),
                        fault_models=("bitflip",),
                        bit_bands=("significant",), samples=2,
                        clean_samples=0, seed=0)
    (plan,), _ = expand(spec)
    assert plan.cell_id == "decode_step/bitflip/significant/2x16/int8"
    m = run_cell(plan).metrics
    assert m.steps == 1 and len(m.detection_latency_hist) == 1


def test_overhead_breakdown_phases_in_artifact(tmp_path):
    from repro.campaign.artifacts import breakdown_markdown

    spec = CampaignSpec(name="t-bd", targets=("gemm_packed",),
                        shapes=((4, 32, 64),), samples=16, seed=1,
                        measure_overhead=True)
    result = run_campaign("bd", [spec], out_dir=str(tmp_path))
    (cell,) = result["cells"]
    bd = cell["metrics"]["overhead_breakdown"]
    assert set(bd) == {"encode", "gemm", "verify"}
    assert all(v > 0 for v in bd.values())
    md = breakdown_markdown(result)
    assert "| cell |" in md and "encode" in md and "%" in md
    assert md in (tmp_path / "BENCH_campaign_bd.md").read_text()
    # cells that don't measure overhead carry no breakdown
    spec2 = CampaignSpec(name="t-nobd", targets=("gemm_packed",),
                         shapes=((4, 32, 64),), samples=8, seed=1)
    r2 = run_campaign("nobd", [spec2], out_dir=None)
    assert r2["cells"][0]["metrics"]["overhead_breakdown"] is None
    assert breakdown_markdown(r2) == ""


def test_run_campaign_with_obs_publishes_cells(tmp_path):
    from repro.obs import Observability

    obs = Observability.create()
    spec = CampaignSpec(name="t-obs", targets=("kv_cache",),
                        shapes=((1, 1, 32, 32),), dtypes=("int8",),
                        samples=16, seed=5)
    result = run_campaign("obsrun", [spec], out_dir=None, obs=obs)
    (cell,) = result["cells"]
    m = cell["metrics"]
    reg = obs.registry
    assert reg.counter("repro_injections_total").value(
        cell=cell["cell_id"]) == m["samples"]
    assert reg.counter("repro_detections_total").value(
        cell=cell["cell_id"]) == m["effective_detected"]
    assert reg.counter("repro_false_positives_total").value(
        cell=cell["cell_id"]) == m["false_positives"]
    cell_evs = [e for e in obs.bus if e.kind == "cell"]
    assert [e.cell_id for e in cell_evs] == [cell["cell_id"]]
    assert cell_evs[0].detector_value == pytest.approx(
        m["detection_rate"])
    assert cell_evs[0].bound == m["analytic_bound"]
    # phase spans recorded under the campaign category
    names = {s.name for s in obs.tracer.spans if s.cat == "campaign"}
    assert {"build", "trials", "clean"} <= names
    paths = obs.write(str(tmp_path))
    assert all(__import__("os").path.exists(p) for p in paths.values())


def test_multi_flip_plan_runs():
    spec = CampaignSpec(name="t", targets=("gemm_packed",),
                        shapes=((4, 32, 64),), samples=32,
                        flips_per_trial=3, seed=11)
    plan = expand(spec)[0][0]
    assert plan.flips == 3
    m = run_cell(plan).metrics
    assert m.corrupted == 32              # 3 distinct victims always change
    assert m.detection_rate > 0.95
