"""serving_soak campaign: artifact structure, per-tenant SLO metrics under
two arrival patterns, online detection of the injected fault."""
import json
import os

import pytest

from repro.campaign.artifacts import load_artifact, markdown_table
from repro.serving.soak import (SoakSpec, quick_soak_spec,
                                run_soak_campaign, soak_plans)


@pytest.fixture(scope="module")
def soak_result(tmp_path_factory):
    out = tmp_path_factory.mktemp("soak")
    spec = SoakSpec(name="serving_soak", arch="llama3.2-1b",
                    arrivals=("poisson", "bursty"), n_requests=16,
                    n_slots=2, rate_rps=300.0, max_new_tokens=8, seed=0)
    result = run_soak_campaign(spec, out_dir=str(out))
    return result, str(out)


def test_artifact_written_and_round_trips(soak_result):
    result, out = soak_result
    path = os.path.join(out, "BENCH_campaign_serving_soak.json")
    assert os.path.exists(path)
    loaded = load_artifact(path)
    assert loaded["campaign"] == "serving_soak"
    assert [c["cell_id"] for c in loaded["cells"]] == \
        [c["cell_id"] for c in result["cells"]]
    json.dumps(loaded)                        # fully serializable
    md = markdown_table(result)
    assert "serving_soak/poisson" in md and "serving_soak/bursty" in md


def test_two_arrival_patterns_with_per_tenant_slo(soak_result):
    result, _ = soak_result
    arrivals = {c["plan"]["arrival"] for c in result["cells"]}
    assert arrivals == {"poisson", "bursty"}
    for c in result["cells"]:
        m = c["metrics"]
        for block in ("slo", "slo_clean"):
            assert set(m[block]) == {"premium", "standard"}
            for t in m[block].values():
                for pct in ("p50", "p95", "p99"):
                    assert pct in t["ttft_ms"]
                    assert pct in t["per_token_ms"]
        assert set(m["slo_degradation"]) == {"premium", "standard"}
        assert m["clean_samples"] > 0         # clean pass actually ran
        assert 0.0 <= m["fp_rate"] <= 1.0


def test_injected_fault_detected_online(soak_result):
    result, _ = soak_result
    detected = [c["metrics"]["detection_rate"] for c in result["cells"]]
    assert any(d == 1.0 for d in detected), detected
    for c in result["cells"]:
        m = c["metrics"]
        assert m["samples"] >= 1
        for inj in m["injections"]:
            assert inj["victim"]
            if inj["detected"]:
                assert inj["latency_steps"] >= 0


def test_soak_plans_sweep_victims_and_patterns():
    spec = SoakSpec(name="s", arch="llama3.2-1b",
                    arrivals=("poisson", "bursty"), n_requests=8,
                    n_slots=2, rate_rps=100.0, max_new_tokens=4, seed=1,
                    victims=(None, "attn.wq"))
    plans = soak_plans(spec)
    assert len(plans) == 4
    assert len({p.cell_id for p in plans}) == 4
    assert {p.victim for p in plans} == {None, "attn.wq"}
    for p in plans:
        assert p.inject_steps and all(s >= 5 for s in p.inject_steps)


def test_custom_tenant_mix_flows_into_cells():
    from repro.serving.soak import run_soak_cell

    spec = SoakSpec(name="s", arch="llama3.2-1b", arrivals=("poisson",),
                    n_requests=4, n_slots=1, rate_rps=200.0,
                    max_new_tokens=2, seed=2,
                    tenants=(("vip", 1.0, "*:policy=log"),))
    (plan,) = soak_plans(spec)
    assert plan.tenants == (("vip", 1.0, "*:policy=log"),)
    cell = run_soak_cell(plan)
    assert set(cell["metrics"]["slo"]) == {"vip"}


def test_quick_spec_defaults():
    spec = quick_soak_spec(seed=3)
    assert spec.n_requests == 200
    assert set(spec.arrivals) == {"poisson", "bursty"}
    assert spec.to_dict()["seed"] == 3
