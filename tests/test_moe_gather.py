"""Gather-based MoE dispatch == one-hot GShard dispatch (hillclimb #2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.common import Ctx
from repro.layers.moe import init_moe, moe_ffn


@pytest.mark.parametrize("top_k,n_experts", [(1, 4), (2, 4), (8, 40)])
def test_gather_matches_onehot(top_k, n_experts):
    d, d_ff = 32, 64
    key = jax.random.key(0)
    p = init_moe(key, d, d_ff, n_experts, quant=False, dtype=jnp.float32)
    from repro.sharding import values_of
    p = values_of(p)
    x = jax.random.normal(jax.random.key(1), (2, 64, d), jnp.float32)

    kw = dict(n_experts=n_experts, top_k=top_k, capacity_factor=1.25,
              group_size=64)
    y0, aux0, _ = moe_ffn(p, x, Ctx(moe_gather=False,
                                    compute_dtype=jnp.float32), **kw)
    y1, aux1, _ = moe_ffn(p, x, Ctx(moe_gather=True,
                                    compute_dtype=jnp.float32), **kw)
    # identical routing + capacity semantics; only summation order differs
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=5e-2, atol=6e-3)
    np.testing.assert_allclose(float(aux1), float(aux0), rtol=1e-6)


def test_gather_capacity_drop_consistent():
    """Force heavy drops (tiny capacity) — both paths must drop the SAME
    tokens (zero contribution), not just close values."""
    d, d_ff, n_experts = 16, 32, 4
    p = init_moe(jax.random.key(0), d, d_ff, n_experts, quant=False,
                 dtype=jnp.float32)
    from repro.sharding import values_of
    p = values_of(p)
    x = jax.random.normal(jax.random.key(2), (1, 32, d), jnp.float32)
    kw = dict(n_experts=n_experts, top_k=2, capacity_factor=0.3,
              group_size=32)
    y0, _, _ = moe_ffn(p, x, Ctx(moe_gather=False,
                                 compute_dtype=jnp.float32), **kw)
    y1, _, _ = moe_ffn(p, x, Ctx(moe_gather=True,
                                 compute_dtype=jnp.float32), **kw)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=5e-2, atol=6e-3)


def test_gather_quantized_path():
    d, d_ff, n_experts = 32, 64, 4
    p = init_moe(jax.random.key(0), d, d_ff, n_experts, quant=True)
    from repro.sharding import values_of
    p = values_of(p)
    x = jax.random.normal(jax.random.key(3), (1, 64, d), jnp.bfloat16)
    kw = dict(n_experts=n_experts, top_k=2, group_size=64)
    y, aux, rep = moe_ffn(p, x, Ctx(moe_gather=True, quant=True), **kw)
    assert y.shape == x.shape
    assert int(rep.gemm_errors) == 0
    assert np.isfinite(np.asarray(y, np.float32)).all()
