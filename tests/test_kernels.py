"""Pallas kernels vs. pure-jnp oracles — shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft_gemm import encode_weight_checksum, pack_encoded_b
from repro.core.inject import flip_bit
from repro.kernels import ref as kref
from repro.kernels.abft_embeddingbag import abft_eb_pallas
from repro.kernels.abft_qgemm import abft_qgemm_pallas
from repro.kernels.quantize_rows import quantize_rows_pallas
from repro.kernels import ops


# ---------------------------- abft_qgemm -----------------------------------

QGEMM_SHAPES = [
    # (m, k, n) — DLRM-ish skinny, tile-aligned, ragged, LLM-wide
    (1, 64, 64),
    (8, 128, 128),
    (16, 256, 512),
    (5, 100, 77),
    (130, 70, 300),
    (2, 800, 3200),
]


@pytest.mark.parametrize("m,k,n", QGEMM_SHAPES)
def test_qgemm_kernel_matches_ref(rng, m, k, n):
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    bp = pack_encoded_b(b)
    c_ref, err_ref = kref.abft_qgemm_ref(a, bp)
    c, err = abft_qgemm_pallas(a, bp, interpret=True)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(err), np.asarray(err_ref))
    assert int(err.sum()) == 0


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (64, 256, 128),
                                      (128, 64, 64), (256, 128, 256)])
def test_qgemm_kernel_block_shapes(rng, bm, bn, bk):
    m, k, n = 48, 160, 200
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    bp = pack_encoded_b(b)
    c_ref, _ = kref.abft_qgemm_ref(a, bp)
    c, err = abft_qgemm_pallas(a, bp, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))
    assert int(err.sum()) == 0


def test_qgemm_kernel_detects_corrupted_weights(rng):
    m, k, n = 8, 64, 96
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    checksum = encode_weight_checksum(b)          # clean checksum
    detected = 0
    for s in range(20):
        b_bad = flip_bit(b, jnp.asarray(s * 41 % (k * n)),
                         jnp.asarray(s % 8))
        bp = pack_encoded_b(b_bad, checksum)      # checksum NOT recomputed
        _, err = abft_qgemm_pallas(a, bp, interpret=True)
        detected += int(err.sum()) > 0
    assert detected == 20  # P[miss] = (3/256)^8 ~ 1e-16 per trial


def test_qgemm_ops_dispatch_xla_path(rng):
    a = jnp.asarray(rng.integers(-128, 128, size=(4, 32)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(32, 16)), jnp.int8)
    bp = pack_encoded_b(b)
    c1, e1 = ops.abft_qgemm(a, bp, use_pallas=False)
    c2, e2 = ops.abft_qgemm(a, bp, interpret=True)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


# --------------------- abft_qgemm: uint8 zero-point path --------------------

def test_qgemm_kernel_uint8_matches_ref(rng):
    # regression: the old wrapper did a bare astype(int8), silently
    # reinterpreting activations >= 128 as negative.  This is the exact
    # distribution benchmarks/gemm_overhead.py generates.
    m, k, n = 20, 256, 512
    a = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    assert int(jnp.max(a)) >= 128            # the wraparound-triggering half
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    bp = pack_encoded_b(b)
    c_ref, err_ref = kref.abft_qgemm_ref(a, bp)
    c, err = abft_qgemm_pallas(a, bp, interpret=True)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(err), np.asarray(err_ref))
    assert int(err.sum()) == 0


def test_qgemm_kernel_uint8_detects_corrupted_weights(rng):
    m, k, n = 8, 64, 96
    a = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    checksum = encode_weight_checksum(b)
    detected = 0
    for s in range(20):
        b_bad = flip_bit(b, jnp.asarray(s * 41 % (k * n)),
                         jnp.asarray(s % 8))
        bp = pack_encoded_b(b_bad, checksum)
        c_ref, err_ref = kref.abft_qgemm_ref(a, bp)
        c, err = abft_qgemm_pallas(a, bp, interpret=True)
        # flags bit-identical to the unsigned reference, not just "some flag"
        np.testing.assert_array_equal(np.asarray(err), np.asarray(err_ref))
        detected += int(err.sum()) > 0
    assert detected == 20


def test_qgemm_kernel_rejects_bad_dtypes(rng):
    a_f = jnp.ones((4, 32), jnp.float32)
    b = jnp.asarray(rng.integers(-128, 128, size=(32, 16)), jnp.int8)
    bp = pack_encoded_b(b)
    with pytest.raises(TypeError, match="int8 or uint8"):
        abft_qgemm_pallas(a_f, bp, interpret=True)
    a = jnp.asarray(rng.integers(-128, 128, size=(4, 32)), jnp.int8)
    with pytest.raises(TypeError, match="int8"):
        abft_qgemm_pallas(a, bp.astype(jnp.int32), interpret=True)


# ----------------- abft_qgemm: bn < LANE multi-tile checksum ----------------

@pytest.mark.parametrize("bn", [32, 64])
@pytest.mark.parametrize("m,k,n", [(8, 64, 96), (5, 100, 77)])
def test_qgemm_kernel_small_bn_clean(rng, bn, m, k, n):
    # the checksum block spans LANE/bn > 1 tiles: lane 0 of the first
    # carries the check, the trailing tiles must stay inert
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    bp = pack_encoded_b(b)
    c_ref, _ = kref.abft_qgemm_ref(a, bp)
    c, err = abft_qgemm_pallas(a, bp, bm=32, bn=bn, bk=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))
    assert int(err.sum()) == 0


@pytest.mark.parametrize("bn", [32, 64])
def test_qgemm_kernel_small_bn_detects(rng, bn):
    m, k, n = 8, 64, 96
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    checksum = encode_weight_checksum(b)
    detected = 0
    for s in range(20):
        b_bad = flip_bit(b, jnp.asarray(s * 41 % (k * n)),
                         jnp.asarray(s % 8))
        bp = pack_encoded_b(b_bad, checksum)
        _, err = abft_qgemm_pallas(a, bp, bm=32, bn=bn, bk=64,
                                   interpret=True)
        detected += int(err.sum()) > 0
    assert detected == 20


# --------------------- abft_qgemm: fused Eq.-1 colcheck ---------------------

@pytest.mark.parametrize("dtype", ["int8", "uint8"])
@pytest.mark.parametrize("bn", [64, 128])
def test_qgemm_kernel_fused_colcheck(rng, dtype, bn):
    from repro.core import encode_activation_checksum
    m, k, n = 12, 100, 200
    lo, hi = (-128, 128) if dtype == "int8" else (0, 256)
    a = jnp.asarray(rng.integers(lo, hi, size=(m, k)), getattr(jnp, dtype))
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    bp = pack_encoded_b(b)
    c, err, col = abft_qgemm_pallas(a, bp, bn=bn, interpret=True,
                                    with_colcheck=True)
    c_ref, err_ref = kref.abft_qgemm_ref(a, bp)
    col_ref = jax.lax.dot_general(
        encode_activation_checksum(a), bp[:, :n].astype(jnp.int32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(err), np.asarray(err_ref))
    np.testing.assert_array_equal(np.asarray(col), np.asarray(col_ref))


def test_qgemm_ops_colcheck_paths_agree(rng):
    # ops-level: the fused kernel's colcheck must equal the XLA wrapper
    # matvec, so the `correct` policy sees the same Eq.-1 reference on
    # both schemes
    a = jnp.asarray(rng.integers(0, 256, size=(6, 64)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(64, 48)), jnp.int8)
    bp = pack_encoded_b(b)
    c_x, e_x, col_x = ops.abft_qgemm(a, bp, use_pallas=False,
                                     with_colcheck=True)
    c_p, e_p, col_p = ops.abft_qgemm(a, bp, use_pallas=True,
                                     interpret=True, with_colcheck=True)
    np.testing.assert_array_equal(np.asarray(c_x), np.asarray(c_p))
    np.testing.assert_array_equal(np.asarray(e_x), np.asarray(e_p))
    np.testing.assert_array_equal(np.asarray(col_x), np.asarray(col_p))


def test_qgemm_correct_policy_pallas_scheme(rng):
    from repro.protect.ops import QGEMM
    from repro.protect.plan import ResolvedRule
    a = jnp.asarray(rng.integers(0, 256, size=(6, 64)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(64, 48)), jnp.int8)
    packed = QGEMM.encode(b)
    c_p, chk_p = QGEMM(packed, a,
                       rule=ResolvedRule(scheme="pallas", policy="correct"))
    c_x, chk_x = QGEMM(packed, a,
                       rule=ResolvedRule(scheme="packed", policy="correct"))
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_x))
    np.testing.assert_array_equal(np.asarray(chk_p.aux),
                                  np.asarray(chk_x.aux))


# ------------------- ops dispatch: explicit scheme wins ---------------------

def test_ops_explicit_false_beats_interpret(rng, monkeypatch):
    # use_pallas=False must take the XLA path even with interpret=True —
    # the old `if use_pallas or interpret` sent it to the kernel anyway.
    # Poison the kernel entry points; the XLA path must never touch them.
    import repro.kernels.abft_embeddingbag as eb_mod
    import repro.kernels.abft_qgemm as qg_mod
    import repro.kernels.quantize_rows as qr_mod

    def _boom(*a, **kw):
        raise AssertionError("explicit use_pallas=False reached Pallas")

    monkeypatch.setattr(qg_mod, "abft_qgemm_pallas", _boom)
    monkeypatch.setattr(eb_mod, "abft_eb_pallas", _boom)
    monkeypatch.setattr(qr_mod, "quantize_rows_pallas", _boom)

    a = jnp.asarray(rng.integers(-128, 128, size=(4, 32)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(32, 16)), jnp.int8)
    bp = pack_encoded_b(b)
    c, err = ops.abft_qgemm(a, bp, use_pallas=False, interpret=True)
    assert int(err.sum()) == 0

    from repro.core.abft_embedding import table_rowsums
    t = jnp.asarray(rng.integers(-128, 128, size=(64, 32)), jnp.int8)
    al = jnp.asarray(rng.uniform(0.01, 0.1, size=64), jnp.float32)
    be = jnp.asarray(rng.uniform(-0.1, 0.1, size=64), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, size=(2, 8)), jnp.int32)
    out = ops.abft_embedding_bag(t, al, be, idx, table_rowsums(t),
                                 use_pallas=False, interpret=True)
    assert int(out.err_count) == 0

    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    q, _, _ = ops.quantize_rows(x, use_pallas=False, interpret=True)
    assert q.dtype == jnp.int8


# ------------- fused vs unfused: deterministic detection parity -------------

def test_qgemm_fused_unfused_err_parity(rng):
    # the SAME stale-checksum flips through the fused Pallas path and the
    # BLAS-2 unfused scheme: Eq. (3b) is one criterion, so the per-row
    # flags must agree flip for flip (the --grid pallas campaign gate is
    # the statistical version of this at scale)
    from repro.protect.ops import QGEMM
    from repro.protect.plan import ResolvedRule
    m, k, n = 8, 64, 96
    a = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    checksum = encode_weight_checksum(b)
    unfused = ResolvedRule(scheme="unfused")
    for s in range(10):
        b_bad = flip_bit(b, jnp.asarray(s * 41 % (k * n)),
                         jnp.asarray(s % 8))
        bp = pack_encoded_b(b_bad, checksum)
        _, err_fused = abft_qgemm_pallas(a, bp, interpret=True)
        _, chk = QGEMM(bp, a, rule=unfused)
        np.testing.assert_array_equal(np.asarray(err_fused).astype(bool),
                                      np.asarray(chk.err_mask))


# ---------------------------- abft_embeddingbag ----------------------------

EB_SHAPES = [
    # (rows, d, bags, pool)
    (256, 32, 4, 10),
    (1024, 64, 10, 100),
    (512, 128, 2, 7),
    (100, 16, 1, 1),
]


@pytest.mark.parametrize("rows,d,bags,pool", EB_SHAPES)
def test_eb_kernel_matches_ref(rng, rows, d, bags, pool):
    t = jnp.asarray(rng.integers(-128, 128, size=(rows, d)), jnp.int8)
    al = jnp.asarray(rng.uniform(0.001, 0.1, size=rows), jnp.float32)
    be = jnp.asarray(rng.uniform(-0.5, 0.5, size=rows), jnp.float32)
    idx = jnp.asarray(rng.integers(0, rows, size=(bags, pool)), jnp.int32)
    r_ref, rsum_ref = kref.abft_eb_ref(t, al, be, idx)
    r, rsum = abft_eb_pallas(t, al, be, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(rsum), np.asarray(rsum_ref),
                               rtol=1e-4, atol=1e-3)


def test_eb_kernel_padding_and_weights(rng):
    t = jnp.asarray(rng.integers(-128, 128, size=(64, 32)), jnp.int8)
    al = jnp.asarray(rng.uniform(0.01, 0.1, size=64), jnp.float32)
    be = jnp.asarray(rng.uniform(-0.1, 0.1, size=64), jnp.float32)
    idx = jnp.asarray([[3, 9, -1, -1], [5, -1, -1, -1]], jnp.int32)
    w = jnp.asarray([[1.0, 2.0, 9.9, 9.9], [0.5, 9.9, 9.9, 9.9]], jnp.float32)
    r_ref, _ = kref.abft_eb_ref(t, al, be, idx, w)
    r, _ = abft_eb_pallas(t, al, be, idx, w, interpret=True)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref), rtol=1e-5,
                               atol=1e-5)


def test_eb_ops_end_to_end_detection(rng):
    from repro.core.abft_embedding import table_rowsums
    t = jnp.asarray(rng.integers(-128, 128, size=(128, 64)), jnp.int8)
    al = jnp.asarray(rng.uniform(0.01, 0.1, size=128), jnp.float32)
    be = jnp.asarray(rng.uniform(-0.1, 0.1, size=128), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, size=(4, 20)), jnp.int32)
    cs = table_rowsums(t)
    out = ops.abft_embedding_bag(t, al, be, idx, cs, interpret=True)
    assert int(out.err_count) == 0
    # corrupt a *read* row's high bit => Eq. 5 must trip
    row = int(idx[0, 0])
    t_bad = t.at[row, 5].set(t[row, 5] ^ np.int8(np.uint8(0x80).view(np.int8)))
    out_bad = ops.abft_embedding_bag(t_bad, al, be, idx, cs, interpret=True)
    assert int(out_bad.err_count) >= 1


# ---------------------------- quantize_rows --------------------------------

@pytest.mark.parametrize("m,n", [(4, 64), (128, 128), (65, 300), (1, 12288)])
def test_quantize_rows_matches_ref(rng, m, n):
    x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    q_ref, a_ref, b_ref = kref.quantize_rows_ref(x)
    q, a, b = quantize_rows_pallas(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_ref), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_rows_dtypes(rng, dtype):
    x = jnp.asarray(rng.normal(size=(8, 256)), dtype)
    q, a, b = quantize_rows_pallas(x, interpret=True)
    recon = np.asarray(a)[:, None] * np.asarray(q, np.float32) + \
        np.asarray(b)[:, None]
    np.testing.assert_allclose(recon, np.asarray(x, np.float32), atol=0.02)
