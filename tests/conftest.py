import numpy as np
import pytest

# NOTE: no XLA_FLAGS device-count override here — tests must see 1 real CPU
# device. Multi-device tests spawn subprocesses that set the flag themselves.


@pytest.fixture
def rng():
    return np.random.default_rng(0)
