"""Validation of the trip-aware HLO cost analyzer (launch.costs) — the
instrument behind every §Roofline / §Perf number."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.costs import analyze_hlo_text, parse_hlo


def _analyze(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text()), compiled


def _xla_cost(compiled):
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca


def test_loop_free_matches_xla_cost_analysis():
    def g(a, b):
        return jnp.tanh(a @ b)

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    mine, compiled = _analyze(g, a, b)
    xla = _xla_cost(compiled)
    assert mine["flops"] == pytest.approx(2 * 128 * 256 * 512, rel=0.02)
    assert mine["bytes"] == pytest.approx(xla["bytes accessed"], rel=0.05)
    assert not mine["flags"]


@pytest.mark.parametrize("L", [4, 8, 16])
def test_scan_trip_multiplication(L):
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    mine, compiled = _analyze(f, x, ws)
    # XLA counts the while body once; the analyzer must count L times.
    assert mine["flops"] == pytest.approx(2 * 64 ** 3 * L, rel=0.02)
    assert _xla_cost(compiled)["flops"] < mine["flops"]
    assert not [f_ for f_ in mine["flags"] if "while" in f_]


def test_nested_scan_trip_product():
    def h(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    mine, _ = _analyze(h, x, ws)
    assert mine["flops"] == pytest.approx(2 * 64 ** 3 * 8 * 4, rel=0.02)


def test_grad_with_remat_counts_recompute():
    L = 8

    def tr(x, ws):
        @jax.checkpoint
        def body(c, w):
            return jnp.tanh(c @ w), None

        def loss(ws):
            y, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(y)
        return jax.grad(loss)(ws)

    mine, _ = _analyze(tr, jnp.ones((64, 64)), jnp.ones((L, 64, 64)))
    # fwd L dots + per-layer (remat fwd 1 + bwd 2) = 4L dots total
    assert mine["flops"] == pytest.approx(2 * 64 ** 3 * L * 4, rel=0.05)


def test_int8_dot_no_staging_copies():
    """The §Perf HC3 fix: int8 operands must reach the dot directly."""
    from repro.kernels.ref import int8_dot

    a = jax.ShapeDtypeStruct((64, 512), jnp.int8)
    b = jax.ShapeDtypeStruct((512, 1024), jnp.int8)
    mine, _ = _analyze(int8_dot, a, b)
    staged = 64 * 512 * 4 + 512 * 1024 * 4     # int32 copies (the bug)
    direct = 64 * 512 + 512 * 1024 + 64 * 1024 * 4
    assert mine["bytes"] < direct + staged / 2, (
        "int32 staging copies are back")


def test_collective_accounting_sharded():
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.costs import analyze_hlo_text
        mesh = jax.make_mesh((8,), ("model",))
        def f(x, w):
            y = x @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, None)))
        xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P()),
                NamedSharding(mesh, P(None, "model"))),
                out_shardings=NamedSharding(mesh, P())).lower(xs, ws)
        r = analyze_hlo_text(c.compile().as_text(), n_partitions=8)
        total = sum(v["count"] for v in r["collectives"].values())
        assert total >= 1, r["collectives"]
        assert r["collective_link_bytes"] > 0
        print("OK")
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-1500:]


def test_parse_hlo_handles_tuple_params():
    txt = """HloModule m

%cond (arg: (s32[], f32[4,4])) -> pred[] {
  %arg = (s32[], f32[4,4]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  ROOT %x = f32[4,4]{1,0} parameter(0)
}
"""
    comps = parse_hlo(txt)
    assert "cond" in comps and "__entry__" in comps
    from repro.launch.costs import _trip_count
    assert _trip_count(comps["cond"]) == 7
