"""Quantization substrate: Eq. (1) pipeline correctness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import (
    dequantize,
    qgemm_f32,
    quantize_channels,
    quantize_rows,
    quantize_tensor,
)
from repro.quant.qtensor import int_matmul


def test_quantize_dequantize_roundtrip(rng):
    x = rng.normal(size=(16, 32)).astype(np.float32)
    q = quantize_tensor(x)
    err = np.abs(dequantize(q) - x).max()
    span = x.max() - x.min()
    assert err <= span / 255.0 + 1e-6  # half-ulp of the quantization grid


def test_rowwise_tighter_than_tensorwise(rng):
    # Rows with wildly different dynamic ranges: per-row must win.
    x = rng.normal(size=(8, 64)).astype(np.float32)
    x[0] *= 100.0
    qt = quantize_tensor(x)
    qr = quantize_rows(x)
    err_t = np.abs(dequantize(qt) - x)[1:].max()
    err_r = np.abs(dequantize(qr) - x)[1:].max()
    assert err_r < err_t


def test_unsigned_rows_dtype(rng):
    x = rng.normal(size=(4, 8)).astype(np.float32)
    q = quantize_rows(x, unsigned=True)
    assert q.values.dtype == jnp.uint8
    assert q.axis == 0


@pytest.mark.parametrize("m,k,n", [(4, 16, 8), (1, 64, 32), (17, 33, 5)])
def test_qgemm_matches_float_gemm(rng, m, k, n):
    """Eq. (1): quantized product approximates the real product."""
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    aq = quantize_rows(a, unsigned=True)
    bq = quantize_channels(b)
    got = np.asarray(qgemm_f32(aq, bq))
    want = a @ b
    # int8 x int8 error budget: ~k * (a_step*|b| + b_step*|a|)
    scale = np.abs(a).max() * np.abs(b).max() * k
    assert np.abs(got - want).max() <= 0.02 * scale + 1e-4


def test_int_matmul_int32_accumulation(rng):
    a = rng.integers(0, 256, size=(8, 300)).astype(np.uint8)
    b = rng.integers(-128, 128, size=(300, 16)).astype(np.int8)
    got = np.asarray(int_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = a.astype(np.int64) @ b.astype(np.int64)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want.astype(np.int32))
