"""Unit tests for ``repro.adapt`` and its wiring: plan ``threshold=``
plumbing, the threshold-event live↔replay counter mirror, schema-v3
round-trips (and v2 back-compat), sweep-artifact calibration, and the
engine / train-loop integration points.

The static-path acceptance criterion lives here too: plans that never
mention ``threshold`` must describe (and therefore lane-key and compile)
exactly as they did before the adaptive stack existed.
"""
import json

import pytest

from repro.adapt import (AdaptiveThresholds, ControllerConfig,
                         ThresholdController, VarianceModel,
                         calibrate_from_sweep)
from repro.obs import EventBus, Monitor, Observability, replay
from repro.obs.events import EVENT_SCHEMA_VERSION, validate_event
from repro.protect import ProtectionPlan, default_plan


def _threshold_lines(registry):
    return sorted(l for l in registry.to_prometheus().splitlines()
                  if l.startswith("repro_threshold"))


# ------------------------------ plan plumbing -------------------------------

def test_plan_parses_and_describes_threshold_mode():
    plan = ProtectionPlan.parse(
        "*:policy=log,embedding_bag:threshold=adaptive")
    r = plan.resolve("embedding_bag")
    assert r.threshold == "adaptive"
    assert plan.resolve("qgemm").threshold == "static"
    assert "threshold=adaptive" in plan.describe()
    # describe -> parse round-trips the mode
    again = ProtectionPlan.parse(plan.describe().split(" ", 1)[-1]
                                 if " " in plan.describe()
                                 else plan.describe())
    assert again.resolve("embedding_bag").threshold == "adaptive"


def test_plan_rejects_unknown_threshold_mode():
    with pytest.raises(ValueError, match="threshold mode"):
        ProtectionPlan.parse("embedding_bag:threshold=magic")


def test_static_plans_describe_without_threshold_token():
    """Bit-identical static path: a plan that never opts in must not
    grow a threshold= token (describe() keys the engine's lane cache,
    so a new token would split every existing lane)."""
    for plan in (default_plan(),
                 ProtectionPlan.parse("*:policy=recompute,kv_cache:on")):
        assert "threshold" not in plan.describe()
        assert plan.resolve("embedding_bag").threshold == "static"


def test_kv_rule_carries_threshold_mode():
    from types import SimpleNamespace

    from repro.protect.runtime import kv_rule
    plan = ProtectionPlan.parse(
        "*:policy=log,kv_cache:on,kv_cache:threshold=adaptive")
    ctx = SimpleNamespace(plan=plan, quant=True)
    assert kv_rule(ctx).threshold == "adaptive"
    # the bf16-gated disabled copy keeps the mode too (field-by-field
    # reconstruction must not drop new ResolvedRule fields)
    ctx_bf16 = SimpleNamespace(plan=plan, quant=False)
    r = kv_rule(ctx_bf16)
    assert not r.enabled and r.threshold == "adaptive"


# ------------------------------ variance model ------------------------------

def test_variance_model_validates_inputs():
    vm = VarianceModel()
    with pytest.raises(ValueError, match="no observations"):
        vm.rel_bound(0.05)
    vm.observe([1.0, 2.0, 3.0])
    with pytest.raises(ValueError, match="fp_quantile"):
        vm.rel_bound(0.0)
    with pytest.raises(ValueError, match="decay"):
        VarianceModel(decay=1.0)
    # clamping: rel_bound(0.5) is the tracked mean (z = 0)
    assert vm.rel_bound(0.5, ceiling=0.5) == 0.5
    assert vm.rel_bound(0.5, floor=99.0) == 99.0


# ------------------------------ controller ----------------------------------

def test_controller_config_validation():
    with pytest.raises(ValueError):
        ControllerConfig(fp_budget=0.0)
    with pytest.raises(ValueError):
        ControllerConfig(floor=1e-2, ceiling=1e-5)
    with pytest.raises(ValueError):
        ControllerConfig(step=1.0)
    with pytest.raises(ValueError):
        ControllerConfig(hysteresis=0.0)


def test_controller_abstains_without_evidence():
    c = ThresholdController("eb", rel_bound=1e-5,
                            config=ControllerConfig(min_checks=100))
    assert c.tick({"checks": 50, "flag_rate_low": 1.0,
                   "flag_rate_high": 1.0}) is None
    assert c.rel_bound == 1e-5
    # abstention ticks do not count toward convergence
    assert not c.converged or c.config.settle_ticks == 0


def test_controller_evidence_window_tracks_moves():
    cfg = ControllerConfig(fp_budget=0.01, min_checks=1,
                           cooldown_ticks=0, window_ticks=16)
    c = ThresholdController("eb", rel_bound=1e-5, config=cfg)
    assert c.evidence_window() == 16          # no moves yet: full window
    c.tick({"checks": 1000, "flag_rate": 0.5, "flag_rate_low": 0.4,
            "flag_rate_high": 0.6})           # overrun -> move
    assert c.evidence_window() == 1           # only post-move evidence
    c.tick({"checks": 10, "flag_rate_low": 0.0, "flag_rate_high": 1.0})
    assert c.evidence_window() == 2


# ------------------------------ event mirror --------------------------------

def _drive_moves(obs, n_ticks=6):
    mon = Monitor(rules=())
    ad = AdaptiveThresholds(config=ControllerConfig(fp_budget=0.02,
                                                    min_checks=10,
                                                    cooldown_ticks=0),
                            obs=obs, source="test.adapt")
    ad.manage("embedding_bag", "premium", rel_bound=1e-5)
    for i in range(n_ticks):
        mon.record_step(float(i), {"embedding_bag": (200, 40)},
                        tenants=("premium",))
        ad.tick(mon, t_s=float(i), step=i)
    return ad


def test_threshold_events_replay_counter_mirror(tmp_path):
    """Every live adjustment's counter/gauge increments are reproduced
    exactly by replay() from the JSONL alone — the counter-mirror
    invariant extended to the ``threshold`` kind."""
    obs = Observability.create()
    ad = _drive_moves(obs)
    assert all(c.adjustments > 0 for c in ad.controllers.values())
    events = [e for e in obs.bus if e.kind == "threshold"]
    assert events
    for e in events:
        assert e.detector_value is not None      # new bound
        assert e.bound is not None               # old bound
        assert e.attrs["direction"] in ("raise", "lower")
        assert e.attrs["tenant"] == "premium"

    path = str(tmp_path / "ev.jsonl")
    obs.bus.to_jsonl(path)
    for d in (json.loads(l) for l in open(path)):
        validate_event(d)
    reg = replay(EventBus.from_jsonl(path))
    assert _threshold_lines(obs.registry) == _threshold_lines(reg)
    assert _threshold_lines(reg)                 # non-vacuous


def test_v2_event_files_still_load(tmp_path):
    """Schema v3 adds the ``threshold`` kind; v2 files (which predate
    it) must keep loading."""
    obs = Observability.create()
    _drive_moves(obs)
    path = str(tmp_path / "ev.jsonl")
    obs.bus.to_jsonl(path)
    lines = open(path).read().splitlines()
    downgraded = []
    for l in lines:
        d = json.loads(l)
        if d["kind"] == "threshold":
            continue                             # v2 never wrote these
        d["schema"] = 2
        downgraded.append(json.dumps(d))
    p2 = str(tmp_path / "v2.jsonl")
    with open(p2, "w") as f:
        f.write("\n".join(downgraded) + "\n")
    EventBus.from_jsonl(p2)                      # must not raise


# ------------------------------ calibration ---------------------------------

def test_calibrate_from_sweep_picks_tightest_budget_holding_bound():
    art = {"cells": [
        {"cell_id": f"thresholds/b{i}", "plan": {
            "target": "embedding_bag", "bit_band": "significant",
            "rel_bound": rb},
         "metrics": {"detection_rate": det, "fp_rate": fp}}
        for i, (rb, det, fp) in enumerate([
            (1e-7, 0.99, 0.20), (1e-6, 0.97, 0.008),
            (1e-5, 0.90, 0.001), (1e-4, 0.60, 0.0)])]}
    assert calibrate_from_sweep(art, fp_budget=0.01) == 1e-6
    # nothing holds the budget -> least-FP point (controller loosens)
    assert calibrate_from_sweep(art, fp_budget=1e-9) == 1e-4
    with pytest.raises(ValueError, match="sweep points"):
        calibrate_from_sweep({"cells": []}, fp_budget=0.01)


# ------------------------------ serving engine ------------------------------

def test_engine_adaptive_loop_moves_bounds_and_rejits():
    """End-to-end engine wiring: a ``threshold=adaptive`` plan gets a
    controller per (op, tenant); on a clean stream the controller
    tightens to its floor, each move re-jits the lane against the new
    bound, requests still complete, and the telemetry carries the
    controller summaries plus typed threshold events."""
    from repro.configs.registry import get_arch
    from repro.serving import ServingEngine, TenantSpec, chat_stream

    from helpers import reduce_cfg

    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    plan = ProtectionPlan.parse("*:policy=log,qgemm:threshold=adaptive",
                                name="t")
    eng = ServingEngine(cfg, [TenantSpec("t", plan)], n_slots=2,
                        max_prompt=8, max_new_tokens=4, seed=0)
    stream = chat_stream(12, tenants={"t": 1.0}, rate_rps=500.0, seed=1,
                         mean_prompt=6, max_prompt=8, mean_output=3,
                         max_output=4)

    ad = AdaptiveThresholds(config=ControllerConfig(
        fp_budget=0.5, hysteresis=1.0, min_checks=1, cooldown_ticks=4,
        settle_ticks=2, floor=5e-6, window_ticks=16))
    with pytest.raises(ValueError, match="monitor"):
        eng.run(stream, adapt=ad)

    obs = Observability.create()
    mon = Monitor(rules=())
    tel = eng.run(stream, obs=obs, monitor=mon, adapt=ad)
    s = tel.summary()
    assert s["per_tenant"]["t"]["completed"] == 12

    ctrl = ad.controllers[("qgemm", "t")]
    assert ctrl.adjustments >= 1                  # clean stream: tightened
    assert ctrl.rel_bound < 1e-5
    assert s["thresholds"] == ad.summary()
    # the lane recompiled against the controller's bound
    lane = eng._lane_of["t"]
    assert lane.plan.resolve("qgemm").rel_bound == ctrl.rel_bound
    moves = [e for e in obs.bus if e.kind == "threshold"]
    assert len(moves) == ctrl.adjustments
    assert all(e.attrs["direction"] == "lower" for e in moves)


# ------------------------------ train loop ----------------------------------

def test_train_loop_requires_monitor_for_adapt(tmp_path):
    from repro.runtime.loop import LoopConfig, TrainLoop
    ad = AdaptiveThresholds()
    with pytest.raises(ValueError, match="monitor"):
        TrainLoop(lambda s, b: (s, {}), None,
                  cfg=LoopConfig(ckpt_dir=str(tmp_path)), adapt=ad)


def test_train_loop_ticks_controllers_and_rebinds_step_fn(tmp_path):
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.loop import LoopConfig, TrainLoop

    class DS:
        def batch_at(self, step):
            rng = np.random.default_rng(step)
            return {"x": jnp.asarray(rng.standard_normal(4), jnp.float32)}

    def step_fn(state, batch):
        # every step: 100 checks, 8 flags on a clean stream -> the
        # (certain) 8% flag rate overruns a 2% budget -> bound raises
        m = {"abft/embedding_bag_errors": jnp.asarray(8, jnp.int32),
             "abft/embedding_bag_checks": jnp.asarray(100, jnp.int32)}
        return {"w": state["w"] + jnp.mean(batch["x"])}, m

    mon = Monitor(rules=())
    ad = AdaptiveThresholds(config=ControllerConfig(
        fp_budget=0.02, min_checks=50, cooldown_ticks=0))
    ad.manage("embedding_bag", "*", rel_bound=1e-5)
    seen = []

    def on_threshold(moved):
        seen.append(dict(moved))
        return step_fn                            # "re-jitted" twin

    loop = TrainLoop(step_fn, DS(),
                     cfg=LoopConfig(ckpt_dir=str(tmp_path / "ck"),
                                    fault_policy="log", save_every=100),
                     monitor=mon, adapt=ad, on_threshold=on_threshold)
    loop.run({"w": jnp.zeros(())}, 6, resume=False)
    ctrl = ad.controllers[("embedding_bag", "*")]
    assert ctrl.adjustments >= 1
    assert seen and all(("embedding_bag", "*") in m for m in seen)
    assert ctrl.rel_bound > 1e-5                  # loosened under overrun
    # the moves landed on the obs bus as typed threshold events
    assert any(e.kind == "threshold" for e in loop.obs.bus)
