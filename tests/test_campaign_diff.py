"""The cross-PR artifact differ and the EB threshold-sweep grid."""
import copy
import json

import pytest

from repro.campaign import (CampaignSpec, diff_artifacts, expand,
                            format_diff, run_campaign, run_diff,
                            threshold_curve)


def _artifact(cells):
    return {
        "schema": 1, "campaign": "t", "seed": 0,
        "env": {"jax": "x", "backend": "cpu", "device_count": 1,
                "python": "3", "platform": "test"},
        "wall_seconds": 0.0, "specs": [], "skipped": [],
        "cells": [{
            "cell_id": cid,
            "plan": {"target": cid.split("/")[0], "bit_band": "all",
                     "rel_bound": None},
            "metrics": {"detection_rate": det, "fp_rate": fp,
                        "overhead": ov, "samples": 100},
            "seconds": 0.0,
        } for cid, det, fp, ov in cells],
    }


OLD = _artifact([
    ("gemm/a", 0.99, 0.00, 0.10),
    ("eb/b", 0.95, 0.02, 0.05),
    ("kv/c", 1.00, 0.00, None),
])


def test_diff_no_regressions_on_identical():
    d = diff_artifacts(OLD, copy.deepcopy(OLD))
    assert d["regressions"] == [] and d["unchanged"] == 3
    assert "0 regression(s)" in format_diff(d)


def test_diff_flags_detection_fp_and_coverage_regressions():
    new = _artifact([
        ("gemm/a", 0.90, 0.00, 0.10),   # detection dropped 9pp
        ("eb/b", 0.95, 0.09, 0.05),     # FP rose 7pp
        # kv/c removed entirely -> coverage regression
        ("new/d", 1.00, 0.00, None),    # added (not a regression)
    ])
    d = diff_artifacts(OLD, new)
    kinds = {(r["cell_id"], r["kind"]) for r in d["regressions"]}
    assert kinds == {("gemm/a", "detection_rate"), ("eb/b", "fp_rate"),
                     ("kv/c", "coverage")}
    assert d["added"] == ["new/d"]
    md = format_diff(d)
    assert "Regressions" in md and "coverage" in md


def test_diff_tolerances_absorb_noise():
    new = copy.deepcopy(OLD)
    new["cells"][0]["metrics"]["detection_rate"] = 0.98   # -1pp < 2pp tol
    new["cells"][1]["metrics"]["fp_rate"] = 0.03          # +1pp < 2pp tol
    assert diff_artifacts(OLD, new)["regressions"] == []
    # tighter tolerance flags them
    d = diff_artifacts(OLD, new, det_tol=0.005, fp_tol=0.005)
    assert len(d["regressions"]) == 2


def test_diff_overhead_opt_in():
    new = copy.deepcopy(OLD)
    new["cells"][0]["metrics"]["overhead"] = 0.50
    assert diff_artifacts(OLD, new)["regressions"] == []     # off by default
    d = diff_artifacts(OLD, new, overhead_tol=0.10)
    assert [r["kind"] for r in d["regressions"]] == ["overhead"]


def test_diff_improvements_tracked():
    new = copy.deepcopy(OLD)
    new["cells"][1]["metrics"]["detection_rate"] = 0.99
    d = diff_artifacts(OLD, new)
    assert d["regressions"] == []
    assert [r["kind"] for r in d["improvements"]] == ["detection_rate"]
    assert d["unchanged"] == 2            # improved cell is not "unchanged"


def test_run_diff_cli_exit_codes(tmp_path):
    old_p, new_p = tmp_path / "old.json", tmp_path / "new.json"
    old_p.write_text(json.dumps(OLD))
    same = run_diff(str(old_p), str(old_p), emit=lambda s: None)
    assert same == 0

    bad = _artifact([("gemm/a", 0.80, 0.0, 0.1)])
    new_p.write_text(json.dumps(bad))
    out_md = tmp_path / "diff.md"
    rc = run_diff(str(old_p), str(new_p), out_path=str(out_md),
                  emit=lambda s: None)
    assert rc == 1
    assert "coverage" in out_md.read_text()       # eb/b + kv/c vanished


def test_main_diff_mode_exit_code(tmp_path):
    from repro.campaign.__main__ import main
    old_p = tmp_path / "old.json"
    old_p.write_text(json.dumps(OLD))
    assert main(["--diff", str(old_p), str(old_p)]) == 0
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(_artifact([("gemm/a", 0.5, 0.0, None)])))
    assert main(["--diff", str(old_p), str(bad_p)]) == 1


# ----------------------- thresholds grid -------------------------------------

def test_thresholds_grid_expands_per_bound_cells():
    from repro.campaign.grids import thresholds_specs
    spec = thresholds_specs(seed=0)[0]
    plans, skipped = expand(spec)
    bounds = {p.rel_bound for p in plans}
    assert bounds == set(spec.rel_bounds)
    ids = [p.cell_id for p in plans]
    assert len(ids) == len(set(ids))
    assert any("rb1e-05" in i for i in ids)


def test_rel_bounds_skip_non_thresholded_targets():
    spec = CampaignSpec(name="t", targets=("gemm_packed",),
                        shapes=((2, 32, 64),), samples=4,
                        rel_bounds=(1e-5, 1e-4))
    plans, skipped = expand(spec)
    assert all(p.rel_bound is None for p in plans)
    assert len(plans) == 1                     # no per-bound duplication
    assert any("no detection threshold" in s["reason"] for s in skipped)


def test_rel_bounds_validation():
    with pytest.raises(ValueError):
        CampaignSpec(name="t", targets=("embedding_bag",), samples=1,
                     rel_bounds=(-1e-5,))


def test_threshold_curve_end_to_end(tmp_path):
    spec = CampaignSpec(
        name="curve", targets=("embedding_bag",),
        bit_bands=("significant",), shapes=((1_000, 64, 4, 20),),
        samples=40, clean_samples=40, rel_bounds=(1e-6, 1e-1), seed=3)
    result = run_campaign("curve", [spec], out_dir=str(tmp_path))
    curves = threshold_curve(result)
    assert set(curves) == {"significant"}
    pts = curves["significant"]
    assert [rb for rb, _, _ in pts] == [1e-6, 1e-1]
    # tighter bound detects at least as much as the very loose one
    assert pts[0][1] >= pts[1][1]
