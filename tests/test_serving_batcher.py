"""Admission-queue + continuous-batcher invariants: no slot leak, FIFO
fairness under burst, bounded-queue load shedding."""
import numpy as np
import pytest

from repro.serving.batcher import ContinuousBatcher
from repro.serving.queue import AdmissionQueue
from repro.serving.workload import Request


def _req(rid, tenant="a", arrival=0.0, out=4):
    return Request(rid=rid, tenant=tenant, arrival_s=arrival,
                   max_new_tokens=out)


def test_fifo_admission_under_burst():
    q = AdmissionQueue()
    for i in range(20):                      # one burst, same instant
        q.push(_req(i), clock_s=0.0)
    b = ContinuousBatcher(4)
    admitted_order = []
    clock = 0.0
    while q or b.occupancy():
        for s in b.admit(q, clock):
            admitted_order.append(s.request.rid)
        # every active request finishes after one "step"
        for s in b.active_slots():
            s.generated = s.request.max_new_tokens
        b.retire_finished()
        clock += 1.0
    assert admitted_order == list(range(20))


def test_no_slot_leak_random_cycles():
    rng = np.random.default_rng(0)
    q = AdmissionQueue()
    b = ContinuousBatcher(3)
    pushed = finished = 0
    for step in range(200):
        for _ in range(int(rng.integers(0, 3))):
            q.push(_req(pushed), clock_s=float(step))
            pushed += 1
        b.admit(q, float(step))
        b.check_invariants()
        for s in b.active_slots():
            if rng.random() < 0.5:
                s.generated = s.request.max_new_tokens
        finished += len(b.retire_finished())
        b.check_invariants()
        assert b.occupancy() + b.free_count() == 3
    # drain
    while q or b.occupancy():
        b.admit(q, 999.0)
        for s in b.active_slots():
            s.generated = s.request.max_new_tokens
        finished += len(b.retire_finished())
    assert finished == pushed


def test_two_lanes_preserve_per_lane_fifo():
    q = AdmissionQueue()
    rids = {"a": [], "b": []}
    for i in range(30):
        tenant = "a" if i % 3 else "b"
        q.push(_req(i, tenant=tenant), clock_s=0.0)
        rids[tenant].append(i)
    lane_a = ContinuousBatcher(2)
    lane_b = ContinuousBatcher(1)
    seen = {"a": [], "b": []}
    while q or lane_a.occupancy() or lane_b.occupancy():
        for lane, t in ((lane_a, "a"), (lane_b, "b")):
            for s in lane.admit(q, 0.0,
                                accept=lambda r, t=t: r.tenant == t):
                assert s.request.tenant == t
                seen[t].append(s.request.rid)
            for s in lane.active_slots():
                s.generated = s.request.max_new_tokens
            lane.retire_finished()
    assert seen == rids                      # per-lane arrival order


def test_queue_bound_rejects_and_counts():
    q = AdmissionQueue(max_depth=2)
    assert q.push(_req(0), 0.0) and q.push(_req(1), 0.0)
    assert not q.push(_req(2, tenant="z"), 0.0)
    assert q.rejected == {"z": 1}
    assert q.depth() == 2
    q.pop_next()
    assert q.push(_req(3, tenant="z"), 0.0)
    assert q.tenant_depths() == {"a": 1, "z": 1}


def test_pop_next_skips_unaccepted_without_reorder():
    q = AdmissionQueue()
    q.push(_req(0, tenant="x"), 0.0)
    q.push(_req(1, tenant="y"), 0.0)
    q.push(_req(2, tenant="x"), 0.0)
    got, _ = q.pop_next(lambda r: r.tenant == "y")
    assert got.rid == 1
    assert [r.rid for r in q.peek_all()] == [0, 2]


def test_retire_unknown_slot_raises_and_double_retire():
    b = ContinuousBatcher(2)
    q = AdmissionQueue()
    q.push(_req(0), 0.0)
    (slot,) = b.admit(q, 0.0)
    b.retire(slot.index)
    with pytest.raises(KeyError):
        b.retire(slot.index)
    b.check_invariants()


def test_queue_wait_measured_from_enqueue():
    q = AdmissionQueue()
    q.push(_req(0), clock_s=1.0)
    b = ContinuousBatcher(1)
    (slot,) = b.admit(q, clock_s=3.5)
    assert slot.queue_wait_s == pytest.approx(2.5)
