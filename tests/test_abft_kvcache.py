"""int8 ABFT KV cache (beyond-paper, EXPERIMENTS HC3): quantization
fidelity, exact checksum detection, and attention-off-int8 correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core.abft_kvcache import (QuantKV, attend_quantized,
                                     dequantize_kv, quantize_kv_rows,
                                     update_kv_row, verify_kv)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (2, 4, 32, 64)) * 3.0
    kv = quantize_kv_rows(x)
    back = dequantize_kv(kv, jnp.float32)
    span = (np.asarray(x).max(-1) - np.asarray(x).min(-1))
    # affine int8: max error ~ span/255/2 per element
    err = np.abs(np.asarray(back) - np.asarray(x)).max(-1)
    assert (err <= span / 255.0 * 0.51 + 1e-6).all()


def test_checksum_clean_and_detects_flip():
    x = jax.random.normal(jax.random.key(1), (1, 2, 16, 32))
    kv = quantize_kv_rows(x)
    _, errs = verify_kv(kv)
    assert int(errs) == 0
    # flip one bit in one cached int8 element
    q = np.asarray(kv.q).copy()
    q[0, 1, 7, 3] ^= 0x10
    bad = QuantKV(jnp.asarray(q), kv.alpha, kv.beta, kv.rowsum)
    err_rows, errs = verify_kv(bad)
    assert int(errs) == 1
    assert bool(err_rows[0, 1, 7])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 7))
def test_checksum_detects_any_bitflip_property(seed, bit):
    """Every single-bit flip in the int8 cache is detected (exact integer
    sums — the analogue of the paper's 100% C-error result)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 1, 8, 16)), jnp.float32)
    kv = quantize_kv_rows(x)
    q = np.asarray(kv.q).copy()
    r, c = rng.integers(8), rng.integers(16)
    q[0, 0, r, c] = np.int8(np.bitwise_xor(
        q[0, 0, r, c], np.int8(np.left_shift(1, bit))))
    changed = q[0, 0, r, c] != np.asarray(kv.q)[0, 0, r, c]
    bad = QuantKV(jnp.asarray(q), kv.alpha, kv.beta, kv.rowsum)
    _, errs = verify_kv(bad)
    assert int(errs) == (1 if changed else 0)


def test_decode_update_then_verify():
    b, kvh, s, dh = 2, 2, 8, 16
    kv = quantize_kv_rows(jnp.zeros((b, kvh, s, dh)))
    new = jax.random.normal(jax.random.key(3), (b, kvh, dh))
    pos = jnp.asarray([2, 5], jnp.int32)
    kv2 = update_kv_row(kv, jnp.arange(b), pos, new)
    _, errs = verify_kv(kv2)
    assert int(errs) == 0
    np.testing.assert_allclose(
        np.asarray(dequantize_kv(kv2, jnp.float32))[0, :, 2],
        np.asarray(new)[0], atol=0.02)


def test_attention_matches_bf16_reference():
    """Attention off the int8 cache ≈ attention off the bf16 cache."""
    b, n_heads, n_kv, s, dh = 2, 8, 2, 32, 16
    k1, k2, k3 = jax.random.split(jax.random.key(4), 3)
    k_cache = jax.random.normal(k1, (b, n_kv, s, dh))
    v_cache = jax.random.normal(k2, (b, n_kv, s, dh))
    q = jax.random.normal(k3, (b, n_heads, dh))
    pos = jnp.asarray([s - 1, s // 2], jnp.int32)

    kv_k, kv_v = quantize_kv_rows(k_cache), quantize_kv_rows(v_cache)
    out, errs = attend_quantized(q, kv_k, kv_v, pos,
                                 n_heads=n_heads, n_kv=n_kv)
    assert int(errs) == 0

    # reference: plain f32 attention on the unquantized cache
    g = n_heads // n_kv
    qg = q.reshape(b, n_kv, g, dh)
    sc = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache) * dh ** -0.5
    valid = jnp.arange(s)[None, None, None, :] <= pos[:, None, None, None]
    sc = jnp.where(valid, sc, -1e30)
    ref = jnp.einsum("bkgs,bksd->bkgd", jax.nn.softmax(sc, -1),
                     v_cache).reshape(b, n_heads, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=0.05, rtol=0.05)


def test_quantize_constant_rows_are_exact_and_verify():
    """Degenerate rows (zero span) hit the 1e-12 span floor: the affine
    params must stay finite, roundtrip exact, and the checksum clean."""
    for fill in (0.0, -3.25, 7.5):
        x = jnp.full((1, 2, 4, 16), fill, jnp.float32)
        kv = quantize_kv_rows(x)
        assert np.isfinite(np.asarray(kv.alpha)).all()
        assert np.isfinite(np.asarray(kv.beta)).all()
        _, errs = verify_kv(kv)
        assert int(errs) == 0
        np.testing.assert_allclose(
            np.asarray(dequantize_kv(kv, jnp.float32)), fill, atol=1e-5)


def test_quantize_extreme_scales_roundtrip():
    """Rows spanning ~1e-6 .. ~1e6 keep the per-row relative error bound
    (per-row affine params make the bound scale-free)."""
    rng = np.random.default_rng(9)
    base = rng.standard_normal((1, 1, 6, 32)).astype(np.float32)
    scales = np.asarray([1e-6, 1e-2, 1.0, 1e2, 1e4, 1e6],
                        np.float32)[None, None, :, None]
    x = jnp.asarray(base * scales)
    kv = quantize_kv_rows(x)
    back = np.asarray(dequantize_kv(kv, jnp.float32))
    span = np.asarray(x).max(-1) - np.asarray(x).min(-1)
    err = np.abs(back - np.asarray(x)).max(-1)
    assert (err <= span / 255.0 * 0.51 + 1e-6).all()
    _, errs = verify_kv(kv)
    assert int(errs) == 0


def test_update_row_overwrite_keeps_checksum_consistent():
    """Overwriting an already-written position must replace the rowsum,
    not accumulate it — repeated decode at one slot stays verifiable."""
    b, kvh, s, dh = 1, 2, 8, 16
    kv = quantize_kv_rows(jax.random.normal(jax.random.key(8),
                                            (b, kvh, s, dh)))
    pos = jnp.asarray([4], jnp.int32)
    for key in (10, 11):
        new = jax.random.normal(jax.random.key(key), (b, kvh, dh))
        kv = update_kv_row(kv, jnp.arange(b), pos, new)
        _, errs = verify_kv(kv)
        assert int(errs) == 0
    np.testing.assert_allclose(
        np.asarray(dequantize_kv(kv, jnp.float32))[0, :, 4],
        np.asarray(new)[0], atol=0.02)


def test_alpha_corruption_changes_values_not_checksum():
    """The rowsum only covers the int8 payload: corrupt affine params
    shift dequantized values without tripping verify_kv.  This documents
    the scheme's boundary (the paper checksums the quantized payload)."""
    x = jax.random.normal(jax.random.key(12), (1, 1, 4, 8))
    kv = quantize_kv_rows(x)
    alpha = np.asarray(kv.alpha).copy()
    alpha[0, 0, 2] *= 4.0
    bad = QuantKV(kv.q, jnp.asarray(alpha), kv.beta, kv.rowsum)
    _, errs = verify_kv(bad)
    assert int(errs) == 0                      # payload checksum silent
    assert not np.allclose(np.asarray(dequantize_kv(bad, jnp.float32)),
                           np.asarray(dequantize_kv(kv, jnp.float32)))


def test_attention_flags_corrupted_cache():
    b, n_heads, n_kv, s, dh = 1, 4, 2, 16, 8
    kv_k = quantize_kv_rows(jax.random.normal(jax.random.key(5),
                                              (b, n_kv, s, dh)))
    kv_v = quantize_kv_rows(jax.random.normal(jax.random.key(6),
                                              (b, n_kv, s, dh)))
    q = jax.random.normal(jax.random.key(7), (b, n_heads, dh))
    pos = jnp.full((b,), s - 1, jnp.int32)
    qk = np.asarray(kv_k.q).copy()
    qk[0, 0, 3, 1] ^= 0x40
    kv_bad = QuantKV(jnp.asarray(qk), kv_k.alpha, kv_k.beta, kv_k.rowsum)
    _, errs = attend_quantized(q, kv_bad, kv_v, pos,
                               n_heads=n_heads, n_kv=n_kv)
    assert int(errs) == 1
