"""repro.paging: allocator, prefix tree, page pools, manager, and the
paged serving engine (prefix sharing, verify-on-touch, detect->rebuild)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft_kvcache import quantize_kv_rows
from repro.paging import (AdmitPlan, PageAllocator, PagedKVManager,
                          PagingConfig, PrefixTree, attend_paged,
                          pack_prompt_pages, page_errors, paged_append,
                          paged_pool, pool_page_bytes, reset_pages,
                          scrub_cache)
from repro.paging.prefixtree import chunk_keys


# ------------------------------ allocator -----------------------------------

def test_allocator_alloc_release_refcount():
    al = PageAllocator(4)
    a, b = al.alloc(), al.alloc()
    assert {a, b} <= {0, 1, 2, 3} and a != b
    assert al.used == 2 and al.free_count == 2 and al.high_water == 2
    al.retain(a)
    assert al.refcount(a) == 2 and al.shared_count == 1
    assert not al.release(a)          # one ref left: not freed
    assert al.release(a)              # freed now
    assert al.used == 1 and al.free_count == 3


def test_allocator_exhaustion_and_reset():
    al = PageAllocator(2)
    assert al.alloc() is not None and al.alloc() is not None
    assert al.alloc() is None
    al.reset()
    assert al.used == 0 and al.free_count == 2 and al.high_water == 0


# ------------------------------ prefix tree ---------------------------------

def test_prefix_tree_match_insert_and_chunk_keys():
    toks = np.arange(16, dtype=np.int64)
    keys = chunk_keys(toks, 4)
    assert len(keys) == 4
    assert chunk_keys(toks, 4) == keys            # deterministic
    tree = PrefixTree()
    parent = None
    for i, k in enumerate(keys[:3]):
        parent = tree.insert(parent, k, page_id=10 + i)
    hit = tree.match(keys)
    assert [n.page_id for n in hit] == [10, 11, 12]
    # divergent suffix only matches the shared head
    other = chunk_keys(np.concatenate([toks[:8], toks[:8] + 99]), 4)
    assert [n.page_id for n in tree.match(other)] == [10, 11]


def test_prefix_tree_evict_page_drops_descendants():
    tree = PrefixTree()
    keys = chunk_keys(np.arange(12, dtype=np.int64), 4)
    parent = None
    for i, k in enumerate(keys):
        parent = tree.insert(parent, k, page_id=i)
    freed = tree.evict_page(1)        # middle of the chain
    assert sorted(freed) == [1, 2]    # the page and its descendant
    assert [n.page_id for n in tree.match(keys)] == [0]


def test_prefix_tree_lru_evicts_leaves_first():
    tree = PrefixTree()
    keys = chunk_keys(np.arange(8, dtype=np.int64), 4)
    parent = tree.insert(None, keys[0], page_id=0)
    tree.insert(parent, keys[1], page_id=1)
    assert tree.evict_lru() == 1      # leaf before its parent
    assert tree.evict_lru() == 0
    assert tree.evict_lru() is None


# ------------------------------ page pools ----------------------------------

def _packed_pool(rng, *, ell=2, kv=2, p=4, dh=8, nc=3, n_pages=8,
                 n_slots=2, max_pages=6):
    """A pool with one slot's prompt packed into pages [0..nc)."""
    pool = paged_pool(n_pages, kv, p, dh, n_slots, max_pages,
                      n_layers=ell)
    src = jnp.asarray(rng.standard_normal((ell, 1, kv, nc * p, dh)),
                      jnp.float32)
    pool = pack_prompt_pages(pool, src, jnp.arange(nc, dtype=jnp.int32))
    tbl = np.full((n_slots, max_pages), -1, np.int32)
    tbl[0, :nc] = np.arange(nc)
    pool = pool._replace(table=jnp.broadcast_to(
        jnp.asarray(tbl), (ell,) + tbl.shape))
    return pool, src


def test_pack_then_verify_clean_and_detects_flip():
    rng = np.random.default_rng(0)
    pool, _ = _packed_pool(rng)
    pos = jnp.asarray([11, 0], jnp.int32)
    per_layer = jax.vmap(page_errors, in_axes=(0, None))
    assert int(jnp.sum(per_layer(pool, pos))) == 0
    q = np.array(pool.q)
    q[1, 2, 0, 1, 3] ^= 0x08          # layer 1, page 2, one payload bit
    bad = pool._replace(q=jnp.asarray(q))
    errs = np.asarray(jnp.sum(per_layer(bad, pos), axis=0))
    assert errs[0, 2] == 1 and errs.sum() == 1   # exact (slot, chunk)


def test_verify_on_touch_masks_beyond_frontier():
    rng = np.random.default_rng(1)
    pool, _ = _packed_pool(rng)
    q = np.array(pool.q)
    q[0, 2, 0, 1, 0] ^= 0x20          # corrupt chunk 2 (rows 8..11)
    bad = pool._replace(q=jnp.asarray(q))
    per_layer = jax.vmap(page_errors, in_axes=(0, None))
    # frontier inside chunk 1: page 2 untouched, no flag
    assert int(jnp.sum(per_layer(bad, jnp.asarray([5, 0])))) == 0
    # frontier reaches chunk 2: flagged
    assert int(jnp.sum(per_layer(bad, jnp.asarray([8, 0])))) == 1


def test_paged_append_maintains_pagesum_and_drops_unmapped():
    rng = np.random.default_rng(2)
    pool, _ = _packed_pool(rng)
    layer0 = jax.tree.map(lambda x: x[0], pool)
    # map a fresh (zeroed) tail page for slot 0's decode chunk 3
    tbl = np.array(layer0.table)
    tbl[0, 3] = 3
    layer0 = layer0._replace(table=jnp.asarray(tbl))
    new = jnp.asarray(rng.standard_normal((2, 2, 8)), jnp.float32)
    # slot 0 appends at pos 12 (chunk 3, offset 0); slot 1 is unmapped
    pos = jnp.asarray([12, 12], jnp.int32)
    out = paged_append(layer0, pos, new)
    # pagesum tracked the append incrementally: frontier verifies clean
    assert int(jnp.sum(page_errors(out, pos))) == 0
    got = np.asarray(out.q[3, :, 0])
    want = np.asarray(quantize_kv_rows(new).q[0])
    np.testing.assert_array_equal(got, want)
    # unmapped slot's write was dropped: prompt pages untouched
    np.testing.assert_array_equal(np.asarray(out.q)[:3],
                                  np.asarray(layer0.q)[:3])


def test_attend_paged_matches_contiguous_quantized():
    from repro.core.abft_kvcache import attend_quantized

    rng = np.random.default_rng(3)
    ell, kv, p, dh, nc = 1, 2, 4, 16, 4
    n_heads, s = 4, nc * p
    kf = rng.standard_normal((1, 1, kv, s, dh)).astype(np.float32)
    vf = rng.standard_normal((1, 1, kv, s, dh)).astype(np.float32)
    pk = paged_pool(8, kv, p, dh, 1, nc, n_layers=ell)
    pv = paged_pool(8, kv, p, dh, 1, nc, n_layers=ell)
    ids = jnp.asarray([3, 1, 4, 0], jnp.int32)    # scrambled page order
    pk = pack_prompt_pages(pk, jnp.asarray(kf), ids)
    pv = pack_prompt_pages(pv, jnp.asarray(vf), ids)
    tbl = jnp.broadcast_to(ids[None, :], (1, nc))[None]
    pk, pv = pk._replace(table=tbl), pv._replace(table=tbl)

    q = jnp.asarray(rng.standard_normal((1, n_heads, dh)), jnp.float32)
    pos = jnp.asarray([s - 2], jnp.int32)
    out, errs, pages = attend_paged(
        q, jax.tree.map(lambda x: x[0], pk), jax.tree.map(lambda x: x[0], pv),
        pos, n_heads=n_heads, n_kv=kv)
    assert int(errs) == 0
    assert int(pages) == 2 * nc       # k + v pools, all pages touched
    ref, ref_errs = attend_quantized(
        q, quantize_kv_rows(jnp.asarray(kf[:, 0].reshape(1, kv, s, dh))),
        quantize_kv_rows(jnp.asarray(vf[:, 0].reshape(1, kv, s, dh))),
        pos, n_heads=n_heads, n_kv=kv)
    assert int(ref_errs) == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_scrub_cache_sums_layers_and_pool_page_bytes():
    rng = np.random.default_rng(4)
    pool, _ = _packed_pool(rng)
    cache = {"attn": {"k": pool, "v": pool}}
    flags = scrub_cache(cache, jnp.asarray([11, 0], jnp.int32))
    assert int(jnp.sum(flags["k"])) == 0 and int(jnp.sum(flags["v"])) == 0
    q = np.array(pool.q)
    q[0, 1, 0, 0, 0] ^= 0x01
    bad = {"attn": {"k": pool._replace(q=jnp.asarray(q)), "v": pool}}
    flags = scrub_cache(bad, jnp.asarray([11, 0], jnp.int32))
    assert int(np.asarray(flags["k"])[0, 1]) == 1
    assert int(jnp.sum(flags["v"])) == 0
    # per-page byte accounting: q + alpha + beta + pagesum, per layer
    ell, kv, p, dh = pool.q.shape[0], pool.q.shape[2], pool.q.shape[3], \
        pool.q.shape[4]
    want = ell * kv * (p * dh + 4 * p + 4 * p + 4)
    assert pool_page_bytes(pool) == want


# ------------------------------ manager -------------------------------------

def _mgr(n_pages=12, n_slots=2, max_pages=6, p=4):
    return PagedKVManager(PagingConfig(page_size=p, n_pages=n_pages),
                          n_slots, max_pages)


def test_manager_admit_share_and_retire_keeps_pages_warm():
    mgr = _mgr()
    toks = np.arange(12, dtype=np.int64)
    plan0 = mgr.admit(0, toks)
    assert plan0.ok and plan0.new_pages == 3 and plan0.shared_pages == 0
    # same prompt on another slot: fully shared, no quantization work
    plan1 = mgr.admit(1, toks)
    assert plan1.ok and plan1.new_pages == 0 and plan1.shared_pages == 3
    assert plan1.tokens(4) == (0, 12)
    np.testing.assert_array_equal(mgr.table[0, :3], mgr.table[1, :3])
    # retire slot 0: tree keeps its reference, pages stay resident
    mgr.retire(0)
    assert (mgr.table[0] == -1).all()
    assert mgr.alloc.used == 3
    # a later identical prompt still hits
    plan2 = mgr.admit(0, toks)
    assert plan2.shared_pages == 3 and plan2.new_pages == 0


def test_manager_decode_page_and_readmit_preserves_tail():
    mgr = _mgr()
    toks = np.arange(8, dtype=np.int64)
    assert mgr.admit(0, toks).ok                   # 2 prompt chunks
    tail = mgr.decode_page(0, 2)
    assert tail is not None and mgr.table[0, 2] == tail
    # corrupt prompt chunk 0 -> evict + readmit must keep the tail page
    assert mgr.evict_corrupt(0, 0)
    mgr.release_prompt(0)
    plan = mgr.readmit(0, toks)
    assert plan.ok and mgr.rebuilds == 1
    assert mgr.table[0, 2] == tail
    # a corrupt decode-tail page is not rebuildable
    assert not mgr.evict_corrupt(0, 2)


def test_manager_admit_rolls_back_on_exhaustion():
    mgr = _mgr(n_pages=4, max_pages=8)
    assert mgr.admit(0, np.arange(12, dtype=np.int64)).ok   # 3 pages
    used = mgr.alloc.used
    # 5 chunks cannot fit in the single free page + no evictable tree
    # pages (all referenced by the resident slot 0)
    plan = mgr.admit(1, 100 + np.arange(20, dtype=np.int64))
    assert not plan.ok
    assert mgr.alloc.used == used              # transactional rollback
    assert (mgr.table[1] == -1).all()


def test_manager_lru_eviction_under_pressure_and_stats():
    mgr = _mgr(n_pages=4, max_pages=4)
    assert mgr.admit(0, np.arange(12, dtype=np.int64)).ok
    mgr.retire(0)                              # 3 warm tree pages
    # a different prompt needs 3 pages: warm ones must be LRU-evicted
    plan = mgr.admit(1, 500 + np.arange(12, dtype=np.int64))
    assert plan.ok and mgr.evictions >= 2
    st = mgr.stats()
    # 3 new pages + the one warm page the free list could still cover
    assert st["pages_resident"] == 4 and st["page_evictions"] >= 2
    assert 0.0 <= st["prefix_hit_rate"] <= 1.0


# ------------------------------ plans (satellite) ---------------------------

def test_plan_from_any_dict_file_and_passthrough(tmp_path):
    from repro.protect import ProtectionPlan
    from repro.protect.plan import OPT_IN_OPS

    assert "kv_cache_paged" in OPT_IN_OPS
    base = ProtectionPlan.parse("*:policy=log,kv_cache_paged:on",
                                name="paged")
    assert ProtectionPlan.from_any(base) is base
    again = ProtectionPlan.from_any(base.to_dict())
    assert again.describe() == base.describe()
    path = tmp_path / "plan.json"
    path.write_text(__import__("json").dumps(base.to_dict()))
    loaded = ProtectionPlan.from_any(f"@{path}")
    assert loaded.describe() == base.describe()
    r = loaded.resolve("kv_cache_paged", "attn")
    assert r.enabled and r.policy == "log"


# ------------------------------ engine --------------------------------------

SMALL_ARCH = "llama3.2-1b"


@pytest.fixture(scope="module")
def paged_engine():
    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.protect import ProtectionPlan
    from repro.serving.engine import ServingEngine, TenantSpec

    cfg = reduce_cfg(get_arch(SMALL_ARCH))
    plan = ProtectionPlan.parse("*:policy=log,kv_cache_paged:on",
                                name="paged")
    return ServingEngine(
        cfg, [TenantSpec("a", plan), TenantSpec("b", plan)],
        n_slots=3, max_prompt=32, max_new_tokens=8,
        paging=PagingConfig(page_size=8, n_pages=40))


def _stream(engine, n=8, seed=3, prefix=16, tenants=None):
    from repro.serving.workload import chat_stream
    return chat_stream(n, tenants=tenants or {"a": 1.0, "b": 1.0},
                       rate_rps=200.0, seed=seed, mean_prompt=24,
                       max_prompt=32, mean_output=6,
                       max_output=engine.max_new_tokens,
                       prefix_len=prefix, prefix_seed=77)


def test_engine_paged_serves_shared_prefix_stream(paged_engine):
    eng = paged_engine
    eng.reset_state()
    tel = eng.run(_stream(eng))
    s = tel.summary()
    assert sum(t["completed"] for t in s["per_tenant"].values()) == 8
    assert sum(t["aborted"] for t in s["per_tenant"].values()) == 0
    # prefix sharing showed up in telemetry AND the pool stats
    shared = sum(t["shared_prefix_tokens"]
                 for t in s["per_tenant"].values())
    assert shared > 0
    st = next(iter(eng.paging_stats().values()))
    assert st["prefix_hit_rate"] > 0.0
    assert st["peak_resident_bytes"] > 0
    # verify-on-touch ran (page compares counted as checks)
    assert s["faults"]["counters"]["kv_cache_paged_checks"] > 0
    assert s["faults"]["counters"]["kv_cache_paged_errors"] == 0


def test_engine_paged_detects_kv_bitflip(paged_engine):
    from repro.serving.engine import FaultInjection

    eng = paged_engine
    eng.reset_state()
    tel = eng.run(_stream(eng), inject=[FaultInjection(
        step=5, target="kv", persistent=True, seed=11)])
    s = tel.summary()
    assert s["faults"]["injections_detected"] == 1
    inj = s["faults"]["injections"][0]
    assert inj["victim"].startswith("kv_page/")
    assert s["faults"]["counters"]["kv_cache_paged_errors"] > 0
    eng.reset_state()


def test_engine_paged_rejects_bad_configs():
    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.serving.engine import ServingEngine, TenantSpec

    cfg = reduce_cfg(get_arch(SMALL_ARCH))
    with pytest.raises(ValueError, match="cannot hold even one"):
        ServingEngine(cfg, [TenantSpec("a")], n_slots=2, max_prompt=32,
                      max_new_tokens=8,
                      paging=PagingConfig(page_size=8, n_pages=2))
    meta = dataclasses.replace(cfg, meta_tokens=1)
    with pytest.raises(ValueError, match="meta_tokens"):
        ServingEngine(meta, [TenantSpec("a")], n_slots=2, max_prompt=32,
                      max_new_tokens=8,
                      paging=PagingConfig(page_size=8, n_pages=64))


def test_engine_rebuild_policy_repairs_online():
    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.protect import ProtectionPlan
    from repro.serving.engine import (FaultInjection, ServingEngine,
                                      TenantSpec)

    cfg = reduce_cfg(get_arch(SMALL_ARCH))
    plan = ProtectionPlan.parse("*:policy=recompute,kv_cache_paged:on",
                                name="paged-fix")
    eng = ServingEngine(cfg, [TenantSpec("a", plan)], n_slots=2,
                        max_prompt=32, max_new_tokens=8,
                        paging=PagingConfig(page_size=8, n_pages=32))
    tel = eng.run(_stream(eng, n=6, tenants={"a": 1.0}),
                  inject=[FaultInjection(
                      step=5, target="kv", persistent=True, seed=7)])
    s = tel.summary()
    st = next(iter(eng.paging_stats().values()))
    assert s["faults"]["injections_detected"] == 1
    assert st["page_rebuilds"] >= 1
    assert sum(t["completed"] for t in s["per_tenant"].values()) == 6
    assert sum(t["aborted"] for t in s["per_tenant"].values()) == 0
