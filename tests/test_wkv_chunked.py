"""Chunked matmul-form WKV6 == per-token recurrence (hillclimb #1 oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.layers.rwkv6 import LOG_W_MIN, wkv_chunked, wkv_recurrent


def _inputs(key, b, s, h, dh, *, heavy_decay=False):
    ks = jax.random.split(key, 6)
    rh = jax.random.normal(ks[0], (b, s, h, dh))
    kh = jax.random.normal(ks[1], (b, s, h, dh))
    vh = jax.random.normal(ks[2], (b, s, h, dh))
    lo = LOG_W_MIN if heavy_decay else -1.0
    lwh = jax.random.uniform(ks[3], (b, s, h, dh), minval=lo, maxval=0.0)
    u = jax.random.normal(ks[4], (h, dh)) * 0.5
    s0 = jax.random.normal(ks[5], (b, h, dh, dh)) * 0.1
    return rh, kh, vh, lwh, u, s0


def test_chunk_over_envelope_rejected():
    rh, kh, vh, lwh, u, s0 = _inputs(jax.random.key(0), 1, 64, 1, 4)
    with pytest.raises(AssertionError, match="envelope"):
        wkv_chunked(rh, kh, vh, lwh, u, s0, chunk=32)


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("heavy", [False, True])
def test_chunked_matches_recurrent(chunk, heavy):
    rh, kh, vh, lwh, u, s0 = _inputs(jax.random.key(0), 2, 64, 3, 8,
                                     heavy_decay=heavy)
    y_ref, s_ref = wkv_recurrent(rh, kh, vh, lwh, u, s0)
    y_chk, s_chk = wkv_chunked(rh, kh, vh, lwh, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_grads_match():
    rh, kh, vh, lwh, u, s0 = _inputs(jax.random.key(1), 1, 32, 2, 8)

    def loss(fn, args):
        y, s = fn(*args, u, s0)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    g_ref = jax.grad(lambda a: loss(wkv_recurrent, a))((rh, kh, vh, lwh))
    g_chk = jax.grad(
        lambda a: loss(lambda *x: wkv_chunked(*x, chunk=8), a))(
        (rh, kh, vh, lwh))
    for a, b in zip(g_ref, g_chk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-3, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 16]),
       st.sampled_from([16, 48, 64]))
def test_chunked_matches_property(seed, chunk, s):
    if s % chunk:
        s = chunk * max(1, s // chunk)
    rh, kh, vh, lwh, u, s0 = _inputs(jax.random.key(seed), 1, s, 2, 4,
                                     heavy_decay=(seed % 2 == 0))
    y_ref, s_ref = wkv_recurrent(rh, kh, vh, lwh, u, s0)
    y_chk, s_chk = wkv_chunked(rh, kh, vh, lwh, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


def test_bf16_operands_close():
    """The §Perf bf16-matmul variant stays within bf16 tolerance of the
    f32 per-token oracle (accumulation is f32 either way)."""
    rh, kh, vh, lwh, u, s0 = _inputs(jax.random.key(3), 2, 64, 2, 8)
    y_ref, s_ref = wkv_recurrent(rh, kh, vh, lwh, u, s0)
    y_b, s_b = wkv_chunked(rh, kh, vh, lwh, u, s0, chunk=16,
                           mm_dtype=jnp.bfloat16)
    # bf16 has ~3 decimal digits; errors compound over 64 tokens
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_ref),
                               rtol=0.15, atol=0.15)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_ref),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("chunk", [8, 16])
def test_pallas_kernel_matches_oracle(chunk):
    """kernels/wkv6_chunked (interpret mode) == per-token oracle."""
    from repro.kernels.wkv6_chunked import wkv_chunked_pallas

    rh, kh, vh, lwh, u, s0 = _inputs(jax.random.key(5), 2, 64, 3, 8,
                                     heavy_decay=True)
    y_ref, s_ref = wkv_recurrent(rh, kh, vh, lwh, u, s0)
    y_k, s_k = wkv_chunked_pallas(rh, kh, vh, lwh, u, s0, chunk=chunk,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_no_overflow_at_worst_case_decay():
    """All-min decay for a full chunk: exponents hit C·|LOG_W_MIN| — must
    stay finite (the f32-safety bound the clamp guarantees)."""
    b, s, h, dh = 1, 32, 1, 4
    rh = jnp.ones((b, s, h, dh))
    kh = jnp.ones((b, s, h, dh))
    vh = jnp.ones((b, s, h, dh))
    lwh = jnp.full((b, s, h, dh), LOG_W_MIN)
    u = jnp.ones((h, dh))
    s0 = jnp.ones((b, h, dh, dh))
    y, st_ = wkv_chunked(rh, kh, vh, lwh, u, s0, chunk=16)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(st_)).all()
    y_ref, _ = wkv_recurrent(rh, kh, vh, lwh, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4)
