"""Training-step campaign targets + multi-step soak executor + the
checked_psum single-device verify path.

Covers the ROADMAP's two missing campaign scenarios end to end: faults at
every seam of the compressed-gradient optimizer pipeline (detection via
the mod-8191 transport checksum, ground truth via clean-twin divergence)
and persistent faults tracked across consecutive train steps with
per-step detection-latency histograms.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.campaign import CampaignSpec, expand, get_target, run_cell
from repro.campaign.grids import training_specs
from repro.campaign.spec import CellPlan, cell_seed
from repro.campaign.targets_training import _inject_point
from repro.runtime.compression import (checked_psum, compress_grads,
                                       compressed_allreduce,
                                       init_compression)


def _plan(target="train_payload", dtype="int8", band="significant",
          steps=1, persistent=False, samples=2, victim=None,
          shape=(2, 8), overhead=False):
    cid = f"test/{target}/{dtype}/{steps}/{persistent}"
    return CellPlan(
        cell_id=cid, target=target, fault_model="bitflip",
        bit_band=band, shape=shape, dtype=dtype, samples=samples,
        clean_samples=1, flips=1, seed=cell_seed(0, cid),
        measure_overhead=overhead, victim=victim, steps=steps,
        persistent=persistent)


# ---------------------------------------------------------------------------
# spec expansion: steps / persistent routing
# ---------------------------------------------------------------------------

def test_expand_steps_and_persistence_gated_on_soak_targets():
    spec = CampaignSpec(
        name="t", targets=("gemm_packed", "train_payload"),
        bit_bands=("significant",), dtypes=("int8",),
        samples=2, steps=3, persistent=(False, True))
    plans, skipped = expand(spec)
    by_target = {}
    for p in plans:
        by_target.setdefault(p.target, []).append(p)
    # soak target: steps honored, transient + persistent variants
    tp = by_target["train_payload"]
    assert sorted((p.steps, p.persistent) for p in tp) \
        == [(3, False), (3, True)]
    assert any(p.cell_id.endswith("/steps3/persistent") for p in tp)
    # single-step target: one cell, steps forced to 1, sweep logged
    gp = by_target["gemm_packed"]
    assert [(p.steps, p.persistent) for p in gp] == [(1, False)]
    reasons = " | ".join(s["reason"] for s in skipped)
    assert "single-step" in reasons and "persistent" in reasons


def test_training_grid_expands_with_soak_cells():
    specs = training_specs(seed=0, quick=True)
    all_plans = []
    for s in specs:
        plans, _ = expand(s)
        all_plans += plans
    targets = {p.target for p in all_plans}
    assert {"train_grad_pre", "train_grad_post", "train_payload",
            "train_moments"} <= targets
    soak = [p for p in all_plans if p.steps > 1]
    assert soak and {p.persistent for p in soak} == {False, True}


def test_inject_point_selection():
    assert _inject_point(_plan("train_grad_pre", "float32")) == "grad_pre"
    assert _inject_point(_plan("train_grad_post", "float32")) \
        == "grad_post"
    assert _inject_point(_plan("train_moments", "float32")) == "moment"
    assert _inject_point(_plan("train_payload", "int8")) == "payload"
    assert _inject_point(_plan("train_payload", "float32")) \
        == "error_feedback"


def test_analytic_bounds_per_seam():
    t = get_target("train_payload")
    assert t.analytic_bound(_plan("train_payload", "int8")) == 1.0
    assert t.analytic_bound(_plan("train_payload", "float32")) == 0.0
    assert get_target("train_moments").analytic_bound(
        _plan("train_moments", "float32")) == 0.0
    assert get_target("train_grad_pre").analytic_bound(
        _plan("train_grad_pre", "float32")) == 0.0


# ---------------------------------------------------------------------------
# checked_psum single-device verify path (the fake-axis shim fix)
# ---------------------------------------------------------------------------

def test_checked_psum_single_device_mismatch_branch():
    grads = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8),
             "b": jnp.ones((8,), jnp.float32)}
    payload, _ = compress_grads(grads, init_compression(grads))
    summed, scale_sum, errs = checked_psum(payload, None)
    assert int(errs) == 0
    # corrupt one payload leaf post-encode: the verify branch must fire
    bad_q = dict(payload["q"], w=payload["q"]["w"].at[0, 0].add(1))
    _, _, errs = checked_psum(dict(payload, q=bad_q), None)
    assert int(errs) == 1
    # corrupt the transported checksum instead: also a mismatch
    bad_cs = dict(payload["checksum"],
                  b=(payload["checksum"]["b"] + 1) % 8191)
    _, _, errs = checked_psum(dict(payload, checksum=bad_cs), None)
    assert int(errs) == 1


def test_compressed_allreduce_single_device_roundtrip():
    grads = {"w": jnp.linspace(-2.0, 2.0, 256).reshape(16, 16)}
    state = init_compression(grads)
    mean, state2, errs = compressed_allreduce(grads, state, None, 1)
    assert int(errs) == 0
    # int8 quantization error bounded by one step of the scale
    scale = float(jnp.max(jnp.abs(grads["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(mean["w"] - grads["w"]))) <= scale
    # error feedback carries exactly the quantization residual
    assert float(jnp.max(jnp.abs(
        state2.error["w"] - (grads["w"] - mean["w"])))) < 1e-6


def test_checked_psum_two_device_pmap_subprocess():
    """The real-collective path: 2 fake host devices, per-device payloads,
    additivity across the axis, and a mid-transit corruption on one
    replica caught by the post-psum verify."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp
        from functools import partial
        from repro.runtime.compression import (checked_psum,
            compress_grads, init_compression)

        def payload_of(x):
            g = {"w": x * jnp.linspace(-1.0, 1.0, 32)}
            p, _ = compress_grads(g, init_compression(g))
            return p

        @partial(jax.pmap, axis_name="data")
        def clean(x):
            _, _, errs = checked_psum(payload_of(x), "data")
            return errs

        @partial(jax.pmap, axis_name="data")
        def corrupted(x):
            p = payload_of(x)
            # flip one payload element on replica 0 only, AFTER encode
            delta = jnp.where(jax.lax.axis_index("data") == 0, 7, 0)
            p = dict(p, q={"w": p["q"]["w"].at[3].add(
                delta.astype(jnp.int8))})
            _, _, errs = checked_psum(p, "data")
            return errs

        xs = jnp.asarray([1.0, 2.0])
        assert [int(e) for e in clean(xs)] == [0, 0]
        errs = corrupted(xs)
        assert all(int(e) == 1 for e in errs), errs
        print("OK")
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_checked_psum_four_device_shard_map_subprocess():
    """The mesh path the multidevice campaign cells run on: 4 fake host
    devices, shard_map over a ``data`` axis, per-shard payloads.  A
    single-shard int8 payload flip must be detected AFTER the reduction
    (the additivity check on the summed payload) — the three clean
    shards' receive-side recomputes see nothing, yet every shard gets
    the post-collective verdict — and a clean run reports zero
    ``comm/errors`` on every shard."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.sharding import make_data_mesh, shard_map
        from repro.runtime.compression import (checked_psum_attributed,
            compress_grads, init_compression)

        mesh = make_data_mesh(4)
        base = jnp.linspace(-1.0, 1.0, 32)

        def run(x, corrupt):
            g = {"w": x[0] * base}         # distinct payload per shard
            p, _ = compress_grads(g, init_compression(g))
            delta = jnp.where(
                (jax.lax.axis_index("data") == 0) & corrupt, 5, 0)
            p = dict(p, q={"w": p["q"]["w"].at[3].add(
                delta.astype(jnp.int8))})
            summed, scales, errs, local = checked_psum_attributed(
                p, "data")
            return errs[None], local[None]

        f = jax.jit(shard_map(run, mesh=mesh,
                              in_specs=(P("data"), P()),
                              out_specs=(P("data"), P("data"))))
        xs = jnp.asarray([1.0, 2.0, 3.0, 4.0])

        errs, local = f(xs, jnp.asarray(False))
        assert [int(e) for e in errs] == [0, 0, 0, 0], errs   # clean: 0
        assert [int(e) for e in local] == [0, 0, 0, 0], local

        errs, local = f(xs, jnp.asarray(True))
        # detected after the collective on EVERY shard...
        assert all(int(e) == 1 for e in errs), errs
        # ...while before it only the corrupted shard could know: the
        # three clean shards' local payload verifies stay silent
        assert [int(e) for e in local] == [1, 0, 0, 0], local
        print("OK")
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# end-to-end cells (small samples — each build compiles a train scan)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_payload_cell_detects_with_zero_latency():
    r = run_cell(_plan("train_payload", "int8", steps=2, samples=2),
                 chunk=4)
    m = r.metrics
    assert m.raw_detection_rate == 1.0          # bound is exactly 1
    assert m.escapes == 0 and m.false_positives == 0
    assert m.steps == 2
    assert m.detection_latency_hist == [2, 0]   # caught in-step
    assert m.mean_detection_latency == 0.0


@pytest.mark.slow
def test_grad_post_cell_escapes_but_diverges():
    """The post-verify window: nothing flags, parameters drift — the cell
    that prices detection coverage, not detection latency."""
    r = run_cell(_plan("train_grad_post", "float32", band="significant",
                       samples=2), chunk=4)
    m = r.metrics
    assert m.raw_detection_rate == 0.0
    assert m.corrupted == m.samples             # f32 update: always moves
    assert m.escapes == m.samples
    assert m.divergence_mean > 0.0
    assert m.loss_divergence_mean >= 0.0


@pytest.mark.slow
def test_error_feedback_fault_surfaces_only_in_multistep():
    """An error-feedback flip is invisible at steps=1 (it corrupts NEXT
    step's payload input) — the soak axis exists precisely for this."""
    r1 = run_cell(_plan("train_payload", "float32", steps=1, samples=2),
                  chunk=4)
    assert r1.metrics.corrupted == 0            # masked within one step
    r2 = run_cell(_plan("train_payload", "float32", steps=3, samples=4),
                  chunk=4)
    # a residual flip can still be rounded away by int8 quantization, so
    # not every trial corrupts — but corruption exists and never flags
    assert r2.metrics.corrupted >= 1
    assert r2.metrics.raw_detection_rate == 0.0       # outside checksum
    assert r2.metrics.escapes == r2.metrics.corrupted
    assert r2.metrics.divergence_mean > 0.0


@pytest.mark.slow
def test_persistent_moment_soak_and_artifact_columns(tmp_path):
    from repro.campaign import (latency_markdown, load_artifact,
                                run_campaign)

    spec = CampaignSpec(
        name="train-soak-test", targets=("train_moments",),
        bit_bands=("significant",), dtypes=("float32",),
        samples=2, clean_samples=1, steps=2, persistent=(True,))
    result = run_campaign("train_soak_test", [spec],
                          out_dir=str(tmp_path))
    art = load_artifact(
        os.path.join(str(tmp_path), "BENCH_campaign_train_soak_test.json"))
    [cell] = art["cells"]
    assert cell["plan"]["steps"] == 2 and cell["plan"]["persistent"]
    m = cell["metrics"]
    assert m["steps"] == 2
    assert len(m["detection_latency_hist"]) == 2
    assert m["divergence_mean"] > 0.0           # moments drift params
    assert m["detection_rate"] is not None
    md = latency_markdown(art)
    assert "latency hist" in md and "train_moments" in md
