"""Property tests for the adaptive-threshold stack (hypothesis-backed;
falls back to the seeded shim in tests/helpers when hypothesis is absent).

Controller properties (closed loop against simulated fp(bound)
environments and adversarial estimate streams):

* **budget monotonicity** — raising the FP budget never *raises* the
  converged bound: extra FP headroom is always spent on detection (note
  this is the physically meaningful direction: a bigger budget tolerates
  more clean flags, so the loop can afford a tighter bound);
* **bounded-step safety** — whatever the estimator claims (including
  inconsistent adversarial sequences), every move is exactly one
  multiplicative ``step``, the bound never exits ``[floor, ceiling]``,
  and moves respect the cooldown;
* **fixed-point stability** — on a zero-FP stream the bound walks
  monotonically to the floor, stops, and converges; it never oscillates.

Variance-model properties:

* on normal residual-ratio streams the derived ``rel_bound(q)`` realizes
  the target FP quantile within the Wilson CI of a fresh sample, across
  round-off bands spanning f32 to bf16 scales, both for pre-divided
  ratios and for (residual, magnitude) pairs;
* on real (non-normal) EB clean-residual streams the quantile mapping
  stays order-correct: a larger target quantile derives a tighter bound
  and realizes at least as many flags.
"""
import math
from statistics import NormalDist

import numpy as np

from helpers import given, settings, st

from repro.adapt import ControllerConfig, ThresholdController, VarianceModel
from repro.campaign.metrics import wilson_interval

# ---------------------------------------------------------------------------
# closed-loop simulation harness
# ---------------------------------------------------------------------------

CHECKS_PER_TICK = 512


def _estimate(fp_rate: float, checks: int = CHECKS_PER_TICK) -> dict:
    """The Monitor-estimate dict for an exact expected flag count."""
    k = int(round(fp_rate * checks))
    lo, hi = wilson_interval(k, checks)
    return {"samples": checks, "checks": checks, "errors": k,
            "flag_rate": k / checks, "flag_rate_low": lo,
            "flag_rate_high": hi}


def _run_env(ctrl: ThresholdController, fp_of_bound, ticks: int) -> None:
    """Drive the controller against a true fp(bound) response curve,
    emulating the Monitor's growing evidence window the way
    ``AdaptiveThresholds.tick`` does (``evidence_window()`` ticks of
    fresh post-move samples)."""
    for _ in range(ticks):
        n = CHECKS_PER_TICK * ctrl.evidence_window()
        ctrl.tick(_estimate(fp_of_bound(ctrl.rel_bound), n))


#: fp(bound) environments: a hard cliff (quantized residuals: fp jumps
#: across one step), and a smooth power-law tail — both monotone
#: nonincreasing in the bound, like any real residual distribution
def _cliff_env(t0):
    return lambda b: 0.4 if b < t0 else 0.0


def _smooth_env(t0):
    return lambda b: min(0.5, 0.01 * (t0 / max(b, 1e-30)) ** 0.7)


BUDGET_PAIRS = ((0.005, 0.02), (0.01, 0.05), (0.02, 0.1))
CLIFFS = (3e-7, 1e-5, 2e-4)


# ---------------------------------------------------------------------------
# controller: budget monotonicity
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(BUDGET_PAIRS), st.sampled_from(CLIFFS),
       st.sampled_from(("cliff", "smooth")))
def test_budget_monotonicity(budgets, t0, env_kind):
    """Same environment, same start, two budgets: the bigger budget's
    converged bound is never above the smaller one's."""
    small, big = budgets
    env = _cliff_env(t0) if env_kind == "cliff" else _smooth_env(t0)
    bounds = {}
    for budget in (small, big):
        ctrl = ThresholdController(
            "eb", rel_bound=1e-4,
            config=ControllerConfig(fp_budget=budget, floor=1e-8,
                                    ceiling=1e-2, min_checks=64,
                                    cooldown_ticks=1, settle_ticks=6))
        _run_env(ctrl, env, 200)
        assert ctrl.converged, (budget, t0, env_kind)
        bounds[budget] = ctrl.rel_bound
    assert bounds[big] <= bounds[small], (bounds, t0, env_kind)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(CLIFFS), st.sampled_from((0.005, 0.02, 0.08)))
def test_cliff_convergence_lands_one_step_above_the_cliff(t0, budget):
    """Steplike fp(bound) (the quantized-residual regime that defeats
    deadband-only control): the loop must converge, hold the budget,
    and stop within one multiplicative step of the cliff edge — not
    limit-cycle across it."""
    cfg = ControllerConfig(fp_budget=budget, floor=1e-8, ceiling=1e-2,
                           min_checks=64, cooldown_ticks=1,
                           settle_ticks=6)
    ctrl = ThresholdController("eb", rel_bound=1e-4, config=cfg)
    _run_env(ctrl, _cliff_env(t0), 200)
    assert ctrl.converged
    assert ctrl.ticks_to_converge is not None
    # above the cliff (fp = 0 <= budget), within one step of its edge
    assert t0 <= ctrl.rel_bound <= t0 * cfg.step * (1 + 1e-9), \
        (ctrl.rel_bound, t0)


# ---------------------------------------------------------------------------
# controller: bounded-step safety under adversarial estimates
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from((1, 2, 4)))
def test_bounded_step_safety_under_adversarial_estimates(seed, cooldown):
    """Arbitrary (even inconsistent) estimator outputs: each tick the
    bound either holds or moves by exactly one ``step`` factor (modulo
    clamping), stays inside [floor, ceiling], and two moves are never
    closer than the cooldown."""
    rng = np.random.default_rng(seed)
    cfg = ControllerConfig(fp_budget=0.02, floor=1e-7, ceiling=1e-3,
                           step=1.5, min_checks=32,
                           cooldown_ticks=cooldown, settle_ticks=8)
    ctrl = ThresholdController("eb", rel_bound=1e-5, config=cfg)
    last_move_tick = None
    for tick in range(150):
        lo = float(rng.uniform(0, 0.5))
        hi = float(rng.uniform(lo, 1.0))
        est = {"checks": int(rng.integers(0, 2000)), "errors": 0,
               "flag_rate": (lo + hi) / 2, "flag_rate_low": lo,
               "flag_rate_high": hi}
        before = ctrl.rel_bound
        moved = ctrl.tick(est)
        after = ctrl.rel_bound
        assert cfg.floor <= after <= cfg.ceiling
        if moved is None:
            assert after == before
        else:
            ratio = after / before
            clamped = after in (cfg.floor, cfg.ceiling)
            assert clamped or math.isclose(
                ratio, cfg.step, rel_tol=1e-9) or math.isclose(
                ratio, 1 / cfg.step, rel_tol=1e-9), (tick, before, after)
            if last_move_tick is not None:
                assert tick - last_move_tick > cooldown
            last_move_tick = tick
        if est["checks"] < cfg.min_checks:
            assert moved is None              # abstained on thin evidence


# ---------------------------------------------------------------------------
# controller: zero-FP fixed point
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.sampled_from((1e-8, 1e-7, 1e-6)), st.integers(0, 2 ** 31 - 1))
def test_zero_fp_stream_converges_to_floor_and_stays(floor, seed):
    """A stream that never flags: the bound tightens monotonically to
    the floor, then never moves again — the fixed point is stable, and
    the controller reports convergence there."""
    del seed                                  # deterministic law: no RNG
    cfg = ControllerConfig(fp_budget=0.02, floor=floor, ceiling=1e-3,
                           min_checks=64, cooldown_ticks=1,
                           settle_ticks=5)
    ctrl = ThresholdController("eb", rel_bound=1e-4, config=cfg)
    est = _estimate(0.0)
    trail = []
    for _ in range(200):
        ctrl.tick(est)
        trail.append(ctrl.rel_bound)
    assert all(b2 <= b1 for b1, b2 in zip(trail, trail[1:]))  # monotone
    assert trail[-1] == floor
    floor_at = trail.index(floor)
    assert all(b == floor for b in trail[floor_at:])          # stable
    assert ctrl.converged and ctrl.ticks_to_converge is not None


# ---------------------------------------------------------------------------
# variance model: derived bound realizes the target quantile
# ---------------------------------------------------------------------------

#: round-off bands: f32 accumulation residual ratios sit ~1e-7, loose
#: mixed-precision ~1e-4, bf16 ~1e-2
SCALES = (1e-7, 1e-4, 1e-2)
QUANTILES = (0.02, 0.05, 0.1)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(SCALES), st.sampled_from(QUANTILES),
       st.integers(0, 2 ** 31 - 1))
def test_variance_model_bound_realizes_target_quantile(scale, q, seed):
    """Normal residual-ratio stream: the fraction of a fresh sample
    flagged by ``rel_bound(q)`` agrees with ``q`` within the Wilson CI
    of the measurement."""
    rng = np.random.default_rng(seed)
    train = rng.normal(10 * scale, scale, 4000)
    test = rng.normal(10 * scale, scale, 800)
    decay = 0.999
    vm = VarianceModel(decay=decay)
    vm.observe(train)
    bound = vm.rel_bound(q)
    k = int(np.sum(test > bound))
    lo, hi = wilson_interval(k, test.size)
    # the Wilson CI covers the test-sample noise; the EWMA-estimated
    # bound carries its own sampling error — delta method: the realized
    # rate shifts by phi(z) per unit of z-estimate error, whose se is
    # sqrt((1 + z^2/2) / ESS) with ESS = (1+d)/(1-d) for EWMA weights
    z = NormalDist().inv_cdf(1 - q)
    ess = (1 + decay) / (1 - decay)
    se_model = (NormalDist().pdf(z)
                * math.sqrt((1 + z * z / 2) / ess))
    assert lo - 4 * se_model <= q <= hi + 4 * se_model, \
        (scale, q, k, bound, vm.mean, vm.std)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(SCALES), st.sampled_from(QUANTILES),
       st.integers(0, 2 ** 31 - 1))
def test_variance_model_ratio_pairs_match_prediv(scale, q, seed):
    """Feeding (residual, magnitude) pairs tracks the same distribution
    as feeding pre-divided ratios — Eq. (5)'s comparison is on the
    ratio, and both entry points must derive the same bound."""
    rng = np.random.default_rng(seed)
    ratios = rng.normal(10 * scale, scale, 3000)
    mags = rng.uniform(50.0, 500.0, 3000)
    a, b = VarianceModel(decay=0.999), VarianceModel(decay=0.999)
    a.observe(ratios)
    b.observe(ratios * mags, mags)
    assert math.isclose(a.rel_bound(q), b.rel_bound(q),
                        rel_tol=1e-6, abs_tol=1e-12)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(("float32", "bfloat16")),
       st.integers(0, 2 ** 31 - 1))
def test_variance_model_order_correct_on_real_eb_residuals(acc, seed):
    """Real clean EB residual streams (f32 and bf16 accumulation) are
    not normal, so the quantile mapping is only approximate there —
    but it must stay order-correct: a larger target quantile gives a
    tighter bound and flags at least as much of a fresh batch."""
    import jax
    import jax.numpy as jnp

    from repro.campaign.adaptive import _ratio_fns, _regime

    shape = (64, 16, 48, 16)
    state = _regime(jax.random.key(seed % 1000), shape)
    clean, _ = _ratio_fns(shape, shape[3],
                          jnp.float32 if acc == "float32"
                          else jnp.bfloat16)
    base = jax.random.key(seed % 1000 + 1)
    train = np.concatenate([
        np.asarray(clean(state, jax.random.fold_in(base, i)), np.float64)
        for i in range(8)])
    test = np.asarray(clean(state, jax.random.fold_in(base, 99)),
                      np.float64)
    vm = VarianceModel(decay=0.999)
    vm.observe(train)
    bounds = [vm.rel_bound(q) for q in (0.01, 0.05, 0.2)]
    assert bounds[0] >= bounds[1] >= bounds[2]
    flags = [int(np.sum(test > b)) for b in bounds]
    assert flags[0] <= flags[1] <= flags[2]
