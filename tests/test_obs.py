"""repro.obs: event schema + JSONL round-trip, the EventBus monoid
against real scan/vmap FaultReports, the Prometheus/Chrome exporters,
and the telemetry percentile fixes that ride along."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.policy import empty_report, merge_reports, op_report
from repro.obs import (EVENT_SCHEMA_VERSION, EventBus, FaultEvent,
                       MetricsRegistry, Observability, Tracer,
                       events_from_metrics, replay, validate_event)


# ------------------------------ events --------------------------------------

def test_event_dict_round_trip_and_validates():
    ev = FaultEvent(op="qgemm", step=7, source="serving.engine",
                    kind="detection", errors=2, checks=5,
                    cell_id="c", shard=1, bit_band="significant",
                    detector_value=0.9, bound=0.99,
                    request_ids=(3, 4), attrs={"lane": 0})
    d = ev.to_dict()
    assert d["schema"] == EVENT_SCHEMA_VERSION
    assert d["request_ids"] == [3, 4]
    validate_event(json.loads(json.dumps(d)))          # JSON-clean
    assert FaultEvent.from_dict(d) == ev


@pytest.mark.parametrize("mutate, msg", [
    (lambda d: d.pop("op"), "missing key 'op'"),
    (lambda d: d.update(kind="explosion"), "not in"),
    (lambda d: d.update(step="seven"), "has type str"),
    (lambda d: d.update(request_ids=[1, "x"]), "list of ints"),
    (lambda d: d.update(schema=EVENT_SCHEMA_VERSION + 1), "newer"),
])
def test_validate_event_rejects(mutate, msg):
    d = FaultEvent(op="qgemm", step=0, source="t").to_dict()
    mutate(d)
    with pytest.raises(ValueError, match=msg):
        validate_event(d)


def test_jsonl_round_trip(tmp_path):
    bus = EventBus()
    bus.emit(FaultEvent(op="qgemm", step=1, source="a", errors=1))
    bus.emit(FaultEvent(op="kv_cache", step=2, source="b",
                        kind="injection", request_ids=(9,)))
    path = bus.to_jsonl(str(tmp_path / "ev.jsonl"))
    back = EventBus.from_jsonl(path)
    assert list(back) == list(bus)


def test_from_jsonl_rejects_bad_record_naming_line(tmp_path):
    p = tmp_path / "bad.jsonl"
    good = FaultEvent(op="qgemm", step=0, source="t").to_dict()
    bad = dict(good, kind="nope")
    p.write_text(json.dumps(good) + "\n" + json.dumps(bad) + "\n")
    with pytest.raises(ValueError, match=r"bad\.jsonl:2: .*kind"):
        EventBus.from_jsonl(str(p))


def test_from_jsonl_reads_schema_v1_files(tmp_path):
    """Migration guard: v1 exports (pre-alert/health kinds) stay
    readable; only records claiming a NEWER schema are rejected."""
    p = tmp_path / "v1.jsonl"
    d = FaultEvent(op="qgemm", step=3, source="old", errors=1,
                   checks=2).to_dict()
    d["schema"] = 1
    p.write_text(json.dumps(d) + "\n")
    (ev,) = EventBus.from_jsonl(str(p))
    assert (ev.op, ev.step, ev.errors) == ("qgemm", 3, 1)
    d["schema"] = EVENT_SCHEMA_VERSION + 1
    p.write_text(json.dumps(d) + "\n")
    with pytest.raises(ValueError, match="newer"):
        EventBus.from_jsonl(str(p))


# -------------------- the bus mirrors the FaultReport monoid -----------------

def _land(report, bus, *, step, source="test"):
    """device_get a FaultReport's metrics and land them as events."""
    metrics = {k: int(v) for k, v in report.as_metrics().items()}
    bus.extend(events_from_metrics(metrics, step=step, source=source))


def test_bus_counters_match_scanned_fault_report():
    """The soak shape from test_report_soak: scan accumulates on device,
    then each step's REPORT lands host-side — per-op bus counters equal
    the final merged report exactly (legacy aliases not double-counted)."""
    per_step = jnp.asarray([0, 2, 0, 1, 3, 0], jnp.int32)

    def body(carry, errs):
        rep = op_report("qgemm", errs)
        return merge_reports(carry, rep), rep

    final, step_reports = jax.lax.scan(body, empty_report(), per_step)
    bus = EventBus()
    for t in range(per_step.shape[0]):
        step_rep = jax.tree.map(lambda x: x[t], step_reports)
        _land(step_rep, bus, step=t)
    assert bus.counters() == {"qgemm": int(final.errors["qgemm"])}
    # one event per FLAGGED step, stamped with that step
    assert [e.step for e in bus] == [1, 3, 4]


def test_merged_bus_counters_are_elementwise_sum():
    """EventBus.merged is the host-side merge_reports: associative, the
    empty bus is the identity, counters sum elementwise — including the
    vmapped-batch totals from the executor's chunk accounting."""
    errs = jnp.asarray([1, 0, 4, 2], jnp.int32)
    batched = jax.vmap(lambda e: op_report("embedding_bag", e))(errs)
    chunk_total = jax.tree.map(lambda x: jnp.sum(x, axis=0), batched)

    a, b = EventBus(), EventBus()
    _land(chunk_total, a, step=0)
    b.emit(FaultEvent(op="qgemm", step=1, source="t", errors=2))
    b.emit(FaultEvent(op="embedding_bag", step=2, source="t", errors=1,
                      kind="false_positive"))

    merged = EventBus.merged(a, b)
    assert merged.counters() == {"embedding_bag": int(errs.sum()) + 1,
                                 "qgemm": 2}
    assert len(EventBus.merged(a, EventBus())) == len(a)
    assoc_l = EventBus.merged(EventBus.merged(a, b), EventBus())
    assoc_r = EventBus.merged(a, EventBus.merged(b, EventBus()))
    assert list(assoc_l) == list(assoc_r)
    # non-detection kinds never count
    a.emit(FaultEvent(op="qgemm", step=9, source="t", kind="injection",
                      errors=5))
    assert a.counters().get("qgemm", 0) == 0


def test_events_from_metrics_ignores_legacy_aliases_and_ceils():
    evs = events_from_metrics(
        {"abft/qgemm_errors": 0.25, "abft/qgemm_checks": 1,
         "abft/gemm_errors": 3,                # legacy alias: ignored
         "kv_cache_errors": 2, "kv_cache_checks": 4},  # bare spelling
        step=5, source="runtime.loop", request_ids=(1,))
    by_op = {e.op: e for e in evs}
    assert set(by_op) == {"qgemm", "kv_cache"}
    assert by_op["qgemm"].errors == 1          # 0.25 ceils, not truncates
    assert by_op["kv_cache"].checks == 4
    assert by_op["kv_cache"].request_ids == (1,)


# ------------------------------ metrics -------------------------------------

def test_counter_gauge_histogram_prometheus_text():
    reg = MetricsRegistry()
    c = reg.counter("repro_detections_total", "detections")
    c.inc(2, cell="a/b", op='q"x')             # label escaping
    c.inc(1, cell="a/b", op='q"x')
    reg.gauge("repro_queue_depth", "depth").set(3, lane="0")
    h = reg.histogram("repro_step_duration_ms", "ms", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v, kind="decode")
    text = reg.to_prometheus()
    assert '# TYPE repro_detections_total counter' in text
    assert 'repro_detections_total{cell="a/b",op="q\\"x"} 3' in text
    assert '# TYPE repro_queue_depth gauge' in text
    assert 'repro_step_duration_ms_bucket{kind="decode",le="1"} 1' in text
    assert 'repro_step_duration_ms_bucket{kind="decode",le="10"} 2' \
        in text
    assert 'repro_step_duration_ms_bucket{kind="decode",le="+Inf"} 3' \
        in text
    assert 'repro_step_duration_ms_count{kind="decode"} 3' in text
    assert 'repro_step_duration_ms_sum{kind="decode"} 55.5' in text


def test_histogram_bucket_edge_semantics():
    """A value exactly on a bucket boundary lands in THAT bucket
    (``le`` is inclusive, matching Prometheus), and values above every
    finite bucket land only in +Inf."""
    reg = MetricsRegistry()
    h = reg.histogram("h_ms", buckets=(1.0, 10.0))
    h.observe(1.0, kind="d")            # exactly on the first edge
    h.observe(10.0, kind="d")           # exactly on the last finite edge
    h.observe(10.0000001, kind="d")     # just past it -> +Inf only
    text = reg.to_prometheus()
    assert 'h_ms_bucket{kind="d",le="1"} 1' in text
    assert 'h_ms_bucket{kind="d",le="10"} 2' in text       # cumulative
    assert 'h_ms_bucket{kind="d",le="+Inf"} 3' in text
    assert 'h_ms_count{kind="d"} 3' in text
    # unsorted bucket args are sorted at construction
    assert reg.histogram("h_ms").buckets == (1.0, 10.0)
    h2 = MetricsRegistry().histogram("h2", buckets=(10.0, 1.0))
    assert h2.buckets == (1.0, 10.0)
    # label sets keep independent bucket counts
    h.observe(0.5, kind="other")
    assert h.count(kind="d") == 3 and h.count(kind="other") == 1


def test_gauge_set_vs_inc_prometheus_output():
    reg = MetricsRegistry()
    g = reg.gauge("g_depth")
    g.set(3, lane="0")
    g.set(1, lane="0")                   # set overwrites
    g.inc(2, lane="1")
    g.inc(-3, lane="1")                  # gauges may go down
    text = reg.to_prometheus()
    assert "# TYPE g_depth gauge" in text
    assert 'g_depth{lane="0"} 1' in text
    assert 'g_depth{lane="1"} -1' in text
    # counters reject what gauges allow
    with pytest.raises(ValueError):
        reg.counter("c_total").inc(-1)


def test_registry_get_or_create_and_type_guard():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)
    assert reg.get("missing") is None


def test_registry_json_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c", "h").inc(2, op="q")
    path = reg.write_json(str(tmp_path / "m.json"))
    d = json.load(open(path))
    assert d["c"]["samples"] == [{"labels": {"op": "q"}, "value": 2.0}]


# ------------------------------- tracer -------------------------------------

def test_tracer_spans_and_chrome_trace(tmp_path):
    t = Tracer()
    with t.span("build", cat="campaign", cell="c1"):
        pass
    t.add_span("decode", cat="serving", start_s=1.0, dur_s=0.5, step=3)
    trace = t.to_chrome_trace()
    events = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in events} == {"build", "decode"}
    assert {e["args"]["name"] for e in meta} == {"campaign", "serving"}
    # one tid per category, µs units
    decode = next(e for e in events if e["name"] == "decode")
    assert decode["ts"] == 1e6 and decode["dur"] == 5e5
    assert len({e["tid"] for e in events}) == 2
    json.load(open(t.write(str(tmp_path / "trace.json"))))
    assert t.total_s("serving") == pytest.approx(0.5)


# --------------------------- bundle + replay --------------------------------

def test_observability_write_and_replay(tmp_path):
    """The counter-mirror invariant, in miniature: emit events paired
    with exactly the live incs the real sites make, then check replay
    reproduces those families line-for-line from the JSONL alone."""
    obs = Observability.create()
    # what observe_metrics does for one flagged step (detection + step
    # summary), what the engine does for one injection
    obs.registry.counter("repro_detections_total").inc(
        1, op="qgemm", source="serving.engine")
    obs.bus.emit(FaultEvent(op="qgemm", step=1, source="serving.engine",
                            errors=2, checks=3, request_ids=(5,)))
    obs.registry.counter("repro_abft_checks_total").inc(
        3, op="qgemm", source="serving.engine")
    obs.registry.counter("repro_abft_errors_total").inc(
        2, op="qgemm", source="serving.engine")
    obs.bus.emit(FaultEvent(op="step", step=1, source="serving.engine",
                            kind="info", errors=2, checks=3,
                            attrs={"channel": "step",
                                   "by_op": {"qgemm": [3, 2]}}))
    obs.registry.counter("repro_injections_total").inc(1, source="s")
    obs.bus.emit(FaultEvent(op="qgemm", step=0, source="s",
                            kind="injection"))
    with obs.tracer.span("phase"):
        pass
    paths = obs.write(str(tmp_path))
    assert set(paths) == {"events", "trace", "prometheus", "metrics_json"}
    for line in open(paths["events"]):
        validate_event(json.loads(line))

    reg = replay(paths["events"])
    assert reg.counter("repro_detections_total").value(
        op="qgemm", source="serving.engine") == 1
    assert reg.counter("repro_abft_errors_total").value(
        op="qgemm", source="serving.engine") == 2
    assert reg.counter("repro_abft_checks_total").value(
        op="qgemm", source="serving.engine") == 3
    assert reg.counter("repro_injections_total").value(source="s") == 1
    fams = ("repro_detections_total", "repro_injections_total",
            "repro_abft_errors_total", "repro_abft_checks_total")
    live = sorted(l for l in obs.registry.to_prometheus().splitlines()
                  if l.startswith(fams))
    rep = sorted(l for l in reg.to_prometheus().splitlines()
                 if l.startswith(fams))
    assert live == rep


def test_observability_incremental_flush_is_crash_durable(tmp_path):
    """With open_incremental, every emitted event is already on disk —
    a killed run (no final write()) loses nothing from the JSONL, and
    the metric snapshot is no staler than ``every`` events."""
    obs = Observability.create()
    paths = obs.open_incremental(str(tmp_path), every=2)
    c = obs.registry.counter("repro_detections_total")
    for i in range(5):
        c.inc(1, op="qgemm", source="t")
        obs.bus.emit(FaultEvent(op="qgemm", step=i, source="t"))
    # simulate a crash: never call obs.write() — read what's on disk
    lines = [json.loads(l) for l in open(paths["events"])]
    assert [d["step"] for d in lines] == [0, 1, 2, 3, 4]
    for d in lines:
        validate_event(d)
    # snapshot rewrites every 2 events: >= 4 detections are visible
    prom = open(paths["prometheus"]).read()
    assert 'repro_detections_total{op="qgemm",source="t"} 4' in prom
    # events emitted BEFORE opening are backfilled, not lost
    obs2 = Observability.create()
    obs2.bus.emit(FaultEvent(op="early", step=0, source="t"))
    p2 = obs2.open_incremental(str(tmp_path), prefix="o2", every=100)
    obs2.bus.emit(FaultEvent(op="late", step=1, source="t"))
    ops = [json.loads(l)["op"] for l in open(p2["events"])]
    assert ops == ["early", "late"]
    # a final write() closes the sink and is a clean full rewrite
    out = obs2.write(str(tmp_path), prefix="o2")
    assert [json.loads(l)["op"] for l in open(out["events"])] == \
        ["early", "late"]
    obs2.bus.emit(FaultEvent(op="after", step=2, source="t"))  # no sink
    assert [json.loads(l)["op"] for l in open(out["events"])] == \
        ["early", "late"]


# --------------------- telemetry percentile degenerate cases -----------------

def test_percentiles_ms_degenerate_inputs():
    import math

    from repro.serving.telemetry import percentiles_ms

    empty = percentiles_ms([])
    assert empty == {"p50": 0.0, "p95": 0.0, "p99": 0.0, "n": 0}
    one = percentiles_ms([0.004])
    assert one["p50"] == one["p95"] == one["p99"] == pytest.approx(4.0)
    assert one["n"] == 1
    # None / non-finite samples are dropped, never NaN-poison the output
    mixed = percentiles_ms([None, float("nan"), float("inf"), 0.002])
    assert mixed["n"] == 1 and mixed["p99"] == pytest.approx(2.0)
    assert not any(isinstance(v, float) and math.isnan(v)
                   for v in mixed.values())
    many = percentiles_ms([0.001 * i for i in range(1, 101)])
    assert many["n"] == 100
    assert many["p50"] == pytest.approx(50.5, rel=0.05)
    assert many["p50"] <= many["p95"] <= many["p99"]


# ------------------- channel accounting + train-loop path -------------------

def test_op_counts_channel_rules():
    from repro.obs.events import op_counts

    # keyed counters win; the legacy aggregate aliases a FaultReport
    # carries alongside them are not double-counted
    both = {"abft/qgemm_errors": 1, "abft/qgemm_checks": 4,
            "abft/gemm_errors": 1}
    assert op_counts(both) == [("qgemm", 4, 1)]
    # legacy-only metrics (hand-written step fns, pre-protect paths)
    # still surface, under the aggregate op names _errors_in counts
    assert op_counts({"abft/gemm_errors": 1}) == [("gemm", 0, 1)]
    assert op_counts({"abft/eb_errors": 2}) == [("embedding_bag", 0, 2)]
    # the checked_psum channel is its own op and ceils like the rest
    assert ("comm", 0, 1) in op_counts({"comm/errors": 0.25})
    evs = events_from_metrics({"comm/errors": 1}, step=2, source="s")
    assert [(e.op, e.errors) for e in evs] == [("comm", 1)]


def test_train_loop_observes_pre_policy_metrics(tmp_path):
    import numpy as np

    from repro.runtime.loop import LoopConfig, TrainLoop

    calls = {}

    def step_fn(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch["x"].mean())
        faulty = int(state["step"]) == 3 and calls.setdefault("f", 0) == 0
        if faulty:
            calls["f"] = 1
        m = {"abft/gemm_errors": jnp.asarray(int(faulty), jnp.int32),
             "loss": jnp.mean((w - batch["x"].mean()) ** 2)}
        return {"w": w, "step": state["step"] + 1}, m

    class DS:
        def batch_at(self, step):
            rng = np.random.default_rng(step)
            return {"x": jnp.asarray(rng.standard_normal(8), jnp.float32)}

    obs = Observability.create()
    cfg = LoopConfig(ckpt_dir=str(tmp_path / "ck"), save_every=100,
                     fault_policy="recompute", log_every=100)
    loop = TrainLoop(step_fn, DS(), cfg=cfg, obs=obs)
    state0 = {"w": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}
    state, _ = loop.run(state0, 6)
    assert loop.stats["recomputes"] == 1
    # the recompute cleared the flag, but the detection event (from the
    # PRE-policy metrics) survives in the stream
    det = [e for e in obs.bus if e.kind == "detection"]
    assert [(e.op, e.step, e.source) for e in det] == \
        [("gemm", 3, "runtime.loop")]
    reg = obs.registry
    assert reg.counter("repro_steps_total").value(
        kind="train", source="runtime.loop") == 6
    assert reg.counter("repro_abft_errors_total").value(
        op="gemm", source="runtime.loop") == 1
    assert reg.get("repro_step_duration_ms").count(kind="train") == 6
    assert len([s for s in obs.tracer.spans
                if s.name == "train_step"]) == 6
