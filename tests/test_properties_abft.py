"""Property tests for the ABFT encoder invariants (hypothesis-backed;
falls back to the seeded shim in tests/helpers when hypothesis is absent).

Three families, matching the detectors' actual guarantees:

* int8 GEMM row/column checksums — with activations drawn from the
  never-`≡ 0 (mod 127)` range, EVERY single-bit flip in B's live region is
  caught, every accumulator (C) flip is caught unconditionally, and clean
  inputs never flag (integer checksums are exact: zero FP by
  construction);
* EmbeddingBag Eq. (5) — clean bags pass at the default ``EB_REL_BOUND``
  in the trained-table regime, and a significant-band flip in an accessed
  row clears the bound by orders of magnitude (the regime is sized so
  α·2^4 dominates the round-off tolerance);
* packed-weight dead lanes — flips in the checksum block's alignment
  zeros (lanes 1..127) are provably inert, and
  :func:`repro.core.inject.random_bitflip_live` never wastes an injection
  on them;

plus the mod-8191 value checksum under the compressed gradient collective:
additivity (the property :func:`checked_psum` relies on) and single-bit
sensitivity (why the payload cell's analytic bound is 1.0).
"""
import jax
import jax.numpy as jnp
import numpy as np

from helpers import given, settings, st

from repro.core import abft_gemm as ag
from repro.core.abft_embedding import (EB_REL_BOUND, abft_embedding_bag,
                                       table_rowsums)
from repro.core.inject import flip_bit, random_bitflip_live
from repro.runtime.compression import (MOD as COMM_MOD, _mod_checksum,
                                       compress_grads, checked_psum,
                                       init_compression)


def _key(*ints):
    k = jax.random.key(ints[0])
    for i in ints[1:]:
        k = jax.random.fold_in(k, i)
    return k


# Shapes come from fixed palettes (not free integer draws): every distinct
# shape is an XLA compile, and the properties quantify over VALUES — seeds
# explore the value space while the compile cache stays warm.
GEMM_SHAPES = ((1, 8, 5), (2, 16, 8), (4, 32, 24), (8, 64, 48))
EB_SHAPES = ((4, 8, 1, 2), (16, 16, 3, 5), (64, 32, 6, 10))


# ---------------------------------------------------------------------------
# GEMM row/column checksums
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.sampled_from(GEMM_SHAPES), st.integers(0, 2 ** 31 - 1))
def test_gemm_b_flip_always_detected_for_nonvanishing_a(shape, seed):
    """A ∈ [1, 127): no activation ≡ 0 (mod 127), so a Δ=±2^j flip in any
    B element shifts every row's Eq. (3b) residue — detection is certain,
    not just 1-(3/256)^m."""
    m, k, n = shape
    ka, kb, kf = jax.random.split(_key(seed), 3)
    a = jax.random.randint(ka, (m, k), 1, 127, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    checksum = ag.encode_weight_checksum(b)

    # clean never flags (exact integer identity)
    out = ag.abft_qgemm(a, b, checksum)
    assert int(out.err_count) == 0

    i1, i2, i3 = jax.random.split(kf, 3)
    idx = int(jax.random.randint(i1, (), 0, b.size))
    bit = int(jax.random.randint(i2, (), 0, 8))
    b_bad = flip_bit(b, jnp.asarray(idx), jnp.asarray(bit))
    assert bool(jnp.any(b_bad != b))
    out = ag.abft_qgemm(a, b_bad, checksum)   # checksum stays CLEAN
    assert int(out.err_count) > 0, (m, k, n, idx, bit)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(GEMM_SHAPES), st.integers(0, 2 ** 31 - 1))
def test_gemm_c_flip_always_detected(shape, seed):
    """Accumulator flips: 2^j mod 127 != 0 for every j, so a single-bit
    C corruption always breaks the row residue — no conditions on A."""
    m, k, n = shape
    ka, kb, kf = jax.random.split(_key(seed), 3)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    b_packed = ag.pack_encoded_b(b)
    c_full = jax.lax.dot_general(a, b_packed, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
    c, check_col = c_full[:, :n], c_full[:, n]
    _, errs = ag.verify_rows(c, check_col)
    assert int(errs) == 0

    i1, i2 = jax.random.split(kf)
    idx = int(jax.random.randint(i1, (), 0, c.size))
    bit = int(jax.random.randint(i2, (), 0, 32))
    c_bad = flip_bit(c, jnp.asarray(idx), jnp.asarray(bit))
    _, errs = ag.verify_rows(c_bad, check_col)
    assert int(errs) > 0, (m, n, idx, bit)


# ---------------------------------------------------------------------------
# EmbeddingBag Eq. (5) at the default bound
# ---------------------------------------------------------------------------

def _eb_regime(seed, rows, d, bags, pool):
    kt, ka, kb, ki = jax.random.split(_key(seed), 4)
    table = jax.random.randint(kt, (rows, d), -128, 128, jnp.int8)
    alphas = jax.random.uniform(ka, (rows,), jnp.float32, 1e-2, 2e-2)
    betas = jax.random.uniform(kb, (rows,), jnp.float32, 0.3, 0.7)
    idx = jax.random.randint(ki, (bags, pool), 0, rows, jnp.int32)
    return table, alphas, betas, idx


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(EB_SHAPES), st.integers(0, 2 ** 31 - 1))
def test_eb_clean_respects_rel_bound(shape, seed):
    rows, d, bags, pool = shape
    table, alphas, betas, idx = _eb_regime(seed, rows, d, bags, pool)
    out = abft_embedding_bag(table, alphas, betas, idx,
                             table_rowsums(table), rel_bound=EB_REL_BOUND)
    assert int(out.err_count) == 0


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(EB_SHAPES), st.integers(0, 2 ** 31 - 1))
def test_eb_significant_flip_in_accessed_row_detected(shape, seed):
    """In this regime the worst-case tolerance is rel_bound · pool · d ·
    (0.02·127 + 0.7) ≈ 1e-2, while the smallest significant-band hit is
    α_min · 2^4 = 0.16 — detection has a >10x margin by construction."""
    rows, d, bags, pool = shape
    table, alphas, betas, idx = _eb_regime(seed, rows, d, bags, pool)
    rowsums = table_rowsums(table)              # encoded from CLEAN table
    kf = jax.random.fold_in(_key(seed), 99)
    k1, k2, k3 = jax.random.split(kf, 3)
    b = int(jax.random.randint(k1, (), 0, bags))
    p = int(jax.random.randint(k2, (), 0, pool))
    row = int(idx[b, p])
    col = int(jax.random.randint(k3, (), 0, d))
    bit = int(jax.random.randint(jax.random.fold_in(k3, 1), (), 4, 8))
    elem = table[row, col]
    bad = flip_bit(elem[None], jnp.zeros((), jnp.int32),
                   jnp.asarray(bit))[0]
    table_bad = table.at[row, col].set(bad)
    out = abft_embedding_bag(table_bad, alphas, betas, idx, rowsums,
                             rel_bound=EB_REL_BOUND)
    assert int(out.err_count) > 0, (rows, d, bags, pool, row, col, bit)


# ---------------------------------------------------------------------------
# Packed-weight dead lanes
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(GEMM_SHAPES[:3]), st.integers(0, 2 ** 31 - 1))
def test_dead_lane_flips_are_inert(shape, seed):
    """Lanes 1..127 of the checksum block are alignment zeros the kernel
    never reads: flipping them changes neither C nor the verdict."""
    m, k, n = shape
    ka, kb, kf = jax.random.split(_key(seed), 3)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    packed = ag.pack_encoded_b(b)
    ref = ag.abft_qgemm_packed(a, packed)

    k1, k2, k3, k4 = jax.random.split(kf, 4)
    row = int(jax.random.randint(k1, (), 0, k))
    lane = int(jax.random.randint(k2, (), 1, ag.LANE))   # dead lanes only
    bit = int(jax.random.randint(k3, (), 0, 8))
    del k4
    idx = row * packed.shape[1] + n + lane
    packed_bad = flip_bit(packed, jnp.asarray(idx), jnp.asarray(bit))
    assert bool(jnp.any(packed_bad != packed))
    out = ag.abft_qgemm_packed(a, packed_bad)
    assert bool(jnp.all(out.c == ref.c))
    assert int(out.err_count) == int(ref.err_count) == 0


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(((4, 6), (16, 24))),
       st.integers(0, 2 ** 31 - 1))
def test_random_bitflip_live_avoids_dead_lanes(shape, seed):
    """Victim positions drawn by the live-region injector always land in
    the weight block or the checksum lane (col <= n), never lanes 1+."""
    k, n = shape
    kb = _key(seed)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    packed = ag.pack_encoded_b(b)
    keys = jax.random.split(jax.random.fold_in(kb, 1), 32)
    flipped = jax.vmap(
        lambda kk: random_bitflip_live(kk, packed, "layers.0.w_packed"))(
            keys)
    for f in np.asarray(flipped != np.asarray(packed)[None]):
        pos = np.argwhere(f)
        assert pos.shape[0] == 1          # exactly one element changed
        assert pos[0][1] <= n, pos        # live region only


# ---------------------------------------------------------------------------
# Gradient-collective value checksum (mod 8191)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.sampled_from((1, 7, 256, 4096)), st.integers(0, 2 ** 31 - 1))
def test_mod_checksum_additive_and_bitflip_sensitive(size, seed):
    ka, kb, kf = jax.random.split(_key(seed), 3)
    qa = jax.random.randint(ka, (size,), -127, 128, jnp.int32)
    qb = jax.random.randint(kb, (size,), -127, 128, jnp.int32)
    # additivity: checksum(a + b) == checksum(a) + checksum(b) (mod M) —
    # the identity checked_psum's expected-vs-got comparison relies on
    lhs = int(_mod_checksum(qa + qb))
    rhs = (int(_mod_checksum(qa)) + int(_mod_checksum(qb))) % COMM_MOD
    assert lhs == rhs

    # single-bit sensitivity on the int8 payload: |Δ| = 2^j <= 128 < M,
    # so the residue always moves — payload detection is exact
    q8 = qa.astype(jnp.int8)
    i1, i2 = jax.random.split(kf)
    idx = int(jax.random.randint(i1, (), 0, size))
    bit = int(jax.random.randint(i2, (), 0, 8))
    q8_bad = flip_bit(q8, jnp.asarray(idx), jnp.asarray(bit))
    assert bool(jnp.any(q8_bad != q8))
    assert int(_mod_checksum(q8_bad.astype(jnp.int32))) \
        != int(_mod_checksum(q8.astype(jnp.int32)))


@settings(max_examples=15, deadline=None)
@given(st.sampled_from((2, 4, 8)), st.sampled_from((5, 64, 300)),
       st.integers(0, 2 ** 31 - 1))
def test_mod_checksum_additive_across_shards(nshards, size, seed):
    """The N-shard identity the sharded campaign cells stand on:
    ``sum(checksum(p_i)) ≡ checksum(psum(p)) (mod 8191)`` for any shard
    count, and a single-bit flip in ANY one shard's int8 payload breaks
    it — |Δ| = 2^j ≤ 128 < 8191 shifts the summed residue while the
    expected value (the mod-sum of per-shard checksums encoded before
    the flip) stays put, so in-transit corruption is detected after the
    collective even though no sender-side recompute could see it."""
    keys = jax.random.split(_key(seed), nshards + 3)
    qs = [jax.random.randint(keys[s], (size,), -127, 128, jnp.int32)
          for s in range(nshards)]
    total = sum(qs)
    expected = sum(int(_mod_checksum(q)) for q in qs) % COMM_MOD
    assert int(_mod_checksum(total)) == expected

    k1, k2, k3 = keys[nshards:]
    shard = int(jax.random.randint(k1, (), 0, nshards))
    idx = int(jax.random.randint(k2, (), 0, size))
    bit = int(jax.random.randint(k3, (), 0, 8))
    q8_bad = flip_bit(qs[shard].astype(jnp.int8), jnp.asarray(idx),
                      jnp.asarray(bit))
    bad_total = total - qs[shard] + q8_bad.astype(jnp.int32)
    assert int(_mod_checksum(bad_total)) != expected, \
        (nshards, size, shard, idx, bit)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from((3, 64, 512)), st.integers(0, 2 ** 31 - 1))
def test_checked_psum_payload_flip_always_caught(size, seed):
    """End-to-end: compress a gradient, corrupt one payload bit in
    transit, and the single-device checked_psum flags it — every time."""
    kg, kf = jax.random.split(_key(seed))
    grads = {"w": jax.random.normal(kg, (size,), jnp.float32)}
    payload, _ = compress_grads(grads, init_compression(grads))
    _, _, errs = checked_psum(payload, None)
    assert int(errs) == 0                     # clean payload: no flags

    i1, i2 = jax.random.split(kf)
    idx = jnp.asarray(int(jax.random.randint(i1, (), 0, size)))
    bit = jnp.asarray(int(jax.random.randint(i2, (), 0, 8)))
    bad = dict(payload, q={"w": flip_bit(payload["q"]["w"], idx, bit)})
    _, _, errs = checked_psum(bad, None)
    assert int(errs) == 1
