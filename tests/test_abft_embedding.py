"""ABFT for EmbeddingBag (paper Alg. 2 / Eq. 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core import abft_embedding as ae
from repro.core.inject import random_bitflip


def _table(rng, rows=512, d=32):
    t = rng.integers(-128, 128, size=(rows, d)).astype(np.int8)
    alphas = rng.uniform(0.001, 0.1, size=rows).astype(np.float32)
    betas = rng.uniform(-0.5, 0.5, size=rows).astype(np.float32)
    return jnp.asarray(t), jnp.asarray(alphas), jnp.asarray(betas)


def test_eb_matches_dense_reference(rng):
    t, a, b = _table(rng)
    idx = jnp.asarray(rng.integers(0, 512, size=(4, 10)))
    r = ae.embedding_bag(t, a, b, idx)
    want = np.zeros((4, 32), np.float32)
    for bag in range(4):
        for i in np.asarray(idx[bag]):
            want[bag] += np.asarray(a)[i] * np.asarray(t)[i] + np.asarray(b)[i]
    # atol floor: jnp and the python loop accumulate in different orders
    np.testing.assert_allclose(np.asarray(r), want, rtol=1e-5, atol=1e-5)


def test_eb_padding_ignored(rng):
    t, a, b = _table(rng)
    idx_full = jnp.asarray([[1, 2, 3, -1, -1]])
    idx_short = jnp.asarray([[1, 2, 3]])
    r1 = ae.embedding_bag(t, a, b, idx_full)
    r2 = ae.embedding_bag(t, a, b, idx_short)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-6)


def test_eb_weighted(rng):
    t, a, b = _table(rng)
    idx = jnp.asarray([[5, 9]])
    w = jnp.asarray([[2.0, 0.5]])
    r = ae.embedding_bag(t, a, b, idx, weights=w)
    want = (2.0 * (np.asarray(a)[5] * np.asarray(t)[5] + np.asarray(b)[5])
            + 0.5 * (np.asarray(a)[9] * np.asarray(t)[9] + np.asarray(b)[9]))
    np.testing.assert_allclose(np.asarray(r)[0], want, rtol=1e-5)


def test_no_false_positive_error_free(rng):
    t, a, b = _table(rng, rows=4096, d=128)
    cs = ae.table_rowsums(t)
    idx = jnp.asarray(rng.integers(0, 4096, size=(10, 100)))
    out = ae.abft_embedding_bag(t, a, b, idx, cs)
    assert int(out.err_count) == 0


def test_detects_high_bit_flip(rng):
    """Paper Table III: high-4-bit flips detected at 99.5%; with a fixed seed
    sweep we assert a strong majority are caught."""
    t, a, b = _table(rng, rows=2048, d=64)
    cs = ae.table_rowsums(t)  # checksums from the CLEAN table
    idx = jnp.asarray(rng.integers(0, 2048, size=(4, 50)))
    detected = 0
    trials = 100
    for s in range(trials):
        key = jax.random.PRNGKey(s)
        # flip a high bit of a row that is actually read
        bag = s % 4
        slot = s % 50
        row = int(idx[bag, slot])
        bit = 4 + (s % 4)  # bits 4..7 (paper's "upper 4 significant bits")
        flat = row * 64 + int(jax.random.randint(key, (), 0, 64))
        t_bad = jnp.asarray(t).reshape(-1).at[flat].set(
            t.reshape(-1)[flat] ^ np.int8(np.uint8(1 << bit).view(np.int8)))
        out = ae.abft_embedding_bag(t_bad.reshape(t.shape), a, b, idx, cs)
        detected += int(out.err_count) > 0
    assert detected >= 90  # paper: 199/200


def test_weighted_checksum_consistency(rng):
    t, a, b = _table(rng)
    cs = ae.table_rowsums(t)
    idx = jnp.asarray(rng.integers(0, 512, size=(3, 7)))
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=(3, 7)).astype(np.float32))
    out = ae.abft_embedding_bag(t, a, b, idx, cs, weights=w)
    assert int(out.err_count) == 0


def test_overhead_model():
    # §V-C: overhead = 1/d + 1/(3m); paper's table: m=100, d=32..256
    assert ae.eb_overhead_model(100, 32) == pytest.approx(1 / 32 + 1 / 300)
    assert ae.eb_overhead_model(100, 256) < 0.01


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 20), st.integers(0, 2 ** 31 - 1))
def test_prop_eq5_exact_up_to_roundoff(bags, pool, seed):
    """Eq. (5) algebraic identity holds for any bag structure/weights."""
    rng = np.random.default_rng(seed)
    t, a, b = _table(rng, rows=128, d=16)
    cs = ae.table_rowsums(t)
    idx = jnp.asarray(rng.integers(-1, 128, size=(bags, pool)))  # with padding
    out = ae.abft_embedding_bag(t, a, b, idx, cs)
    assert int(out.err_count) == 0
