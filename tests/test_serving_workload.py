"""Workload generators: seeded determinism, arrival-process shape, and
payload layouts (dlrm lookups reuse the data/pipeline padded format)."""
import numpy as np
import pytest

from repro.serving.workload import (bursty_arrivals, chat_stream,
                                    dlrm_stream, make_arrivals,
                                    poisson_arrivals, sample_tenants,
                                    trace_arrivals)


def test_poisson_rate_and_determinism():
    rng = np.random.default_rng(7)
    t = poisson_arrivals(50.0, 5000, rng)
    assert np.all(np.diff(t) >= 0)
    mean_gap = float(np.mean(np.diff(t)))
    assert 0.8 / 50.0 < mean_gap < 1.2 / 50.0
    t2 = poisson_arrivals(50.0, 5000, np.random.default_rng(7))
    np.testing.assert_allclose(t, t2)


def test_bursty_arrivals_cluster():
    rng = np.random.default_rng(0)
    t = bursty_arrivals(100.0, 64, rng, burst_size=8,
                        burst_spread_s=1e-4)
    assert np.all(np.diff(t) >= 0)
    gaps = np.diff(t)
    # most gaps are intra-burst (tiny), a few are inter-burst (large)
    assert np.sum(gaps < 1e-3) >= 48
    assert np.sum(gaps > 1e-2) >= 3


def test_trace_replay_tiles_past_span():
    t = trace_arrivals([0.0, 0.5, 1.0], 7, np.random.default_rng(0))
    assert len(t) == 7
    np.testing.assert_allclose(t[:3], [0.0, 0.5, 1.0])
    np.testing.assert_allclose(t[3:6], [1.0, 1.5, 2.0])


def test_make_arrivals_validates():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        make_arrivals("weird", 1.0, 4, rng)
    with pytest.raises(ValueError):
        make_arrivals("trace", 1.0, 4, rng)          # needs a trace
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 4, rng)


def test_sample_tenants_weights():
    rng = np.random.default_rng(0)
    who = sample_tenants({"a": 3.0, "b": 1.0}, 4000, rng)
    frac_a = who.count("a") / 4000
    assert 0.70 < frac_a < 0.80
    with pytest.raises(ValueError):
        sample_tenants({"a": -1.0}, 4, rng)


def test_chat_stream_deterministic_and_bounded():
    kw = dict(tenants={"p": 1.0, "q": 2.0}, rate_rps=100.0, seed=3,
              mean_prompt=16, max_prompt=32, mean_output=6, max_output=12)
    s1 = chat_stream(50, **kw)
    s2 = chat_stream(50, **kw)
    assert [(r.rid, r.tenant, r.arrival_s, r.prompt_len,
             r.max_new_tokens, r.seed) for r in s1] == \
           [(r.rid, r.tenant, r.arrival_s, r.prompt_len,
             r.max_new_tokens, r.seed) for r in s2]
    for r in s1:
        assert 4 <= r.prompt_len <= 32
        assert 1 <= r.max_new_tokens <= 12
        assert r.kind == "chat"
    assert [r.arrival_s for r in s1] == sorted(r.arrival_s for r in s1)


def test_dlrm_stream_payload_matches_pipeline_layout():
    s = dlrm_stream(5, tenants={"rec": 1.0}, seed=0, lookup_batch=6,
                    table_rows=100, n_tables=4, max_pool=8)
    for r in s:
        assert r.kind == "dlrm" and r.max_new_tokens == 0
        dense, bags = r.payload["dense"], r.payload["bags"]
        assert dense.shape == (6, 13)          # EXTRAS.n_dense
        assert bags.shape == (4, 6, 8)
        assert bags.dtype == np.int32
        live = bags[bags >= 0]
        assert live.size and live.max() < 100
        assert (bags == -1).any()              # variable pooling pads
        # pad layout: -1s trail the live prefix of each bag
        for t in range(4):
            for b in range(6):
                row = bags[t, b]
                n_live = int((row >= 0).sum())
                assert (row[:n_live] >= 0).all()
                assert (row[n_live:] == -1).all()


def test_request_kind_validated():
    from repro.serving.workload import Request
    with pytest.raises(ValueError):
        Request(rid=0, tenant="a", arrival_s=0.0, kind="video")
