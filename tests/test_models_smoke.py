"""Per-assigned-architecture smoke tests (reduced configs, CPU, 1 device).

For each of the 10 archs: one train forward/loss (shape + finiteness), one
prefill + decode step (cache plumbing), both in bf16-compute float-param
mode and — for a subset — in int8+ABFT serving mode (reports must be clean).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.layers.common import Ctx
from repro.models.base import build_model
from repro.sharding import values_of
from tests.helpers import small_arch

LM_ARCHS = [a for a in ARCHS if a != "dlrm"]


def _batch(model, key, S=16, B=2):
    cfg = model.cfg
    b = {}
    text_len = S
    if cfg.family == "vlm":
        text_len = S - cfg.n_patches
        b["patches"] = jax.random.normal(key, (B, cfg.n_patches,
                                               cfg.patch_dim), jnp.float32)
    if cfg.family == "hybrid":
        text_len = S - cfg.meta_tokens
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                                        jnp.float32)
    b["tokens"] = jax.random.randint(key, (B, text_len), 0, cfg.vocab)
    b["labels"] = jax.random.randint(key, (B, text_len), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_loss_finite(arch):
    cfg = small_arch(arch)
    model = build_model(cfg, max_pos=64)
    key = jax.random.PRNGKey(0)
    params = values_of(model.init(key))
    batch = _batch(model, key)
    ctx = Ctx(compute_dtype=jnp.float32)
    loss, (metrics, rep) = jax.jit(
        lambda p, b: model.loss(p, b, ctx))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    assert int(rep.total_errors()) == 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_then_decode(arch):
    cfg = small_arch(arch)
    model = build_model(cfg, max_pos=64)
    key = jax.random.PRNGKey(1)
    params = values_of(model.init(key))
    batch = _batch(model, key)
    batch.pop("labels")
    ctx = Ctx(compute_dtype=jnp.float32)
    cache_len = 32

    logits, cache, rep = jax.jit(
        lambda p, b: model.prefill(p, b, ctx, cache_len))(params, batch)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # one decode step continuing from the prefill
    prefill_len = batch["tokens"].shape[1] + cfg.meta_tokens + \
        (cfg.n_patches if cfg.family == "vlm" else 0)
    tokens = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    pos = jnp.full((2,), prefill_len, jnp.int32)
    if cfg.family == "ssm":
        cache2 = cache            # rwkv prefill returns plain state values
    else:
        cache2 = values_of(model.init_cache(2, cache_len,
                                            dtype=jnp.float32))
        cache2 = jax.tree.map(lambda z, c: z.at[..., :c.shape[-2], :].set(
            c.astype(z.dtype)) if z.ndim >= 4 else z, cache2, cache2)
        # decode against the real prefill cache when shapes line up
        cache2 = cache if _tree_shapes_match(cache, cache2) else cache2
    logits2, cache3, rep2 = jax.jit(
        lambda p, c, t, q: model.decode(p, c, t, q, ctx))(
        params, _stack_if_needed(cache2, cfg), tokens, pos)
    assert logits2.shape == (2, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(rep2.total_errors()) == 0


def _tree_shapes_match(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        x.shape == y.shape for x, y in zip(la, lb))


def _stack_if_needed(cache, cfg):
    """prefill returns per-layer stacked cache already (scan ys)."""
    return cache


@pytest.mark.parametrize("arch", ["llama3.2-1b", "granite-moe-3b-a800m",
                                  "rwkv6-1.6b", "hymba-1.5b"])
def test_quantized_abft_serving_clean(arch):
    """int8+ABFT serving: error-free run must report zero errors and
    nonzero checks (the technique is actually in the graph)."""
    cfg = small_arch(arch)
    model = build_model(cfg, max_pos=64)
    key = jax.random.PRNGKey(2)
    params = values_of(model.init(key, quant=True))
    batch = _batch(model, key)
    batch.pop("labels")
    ctx = Ctx(quant=True, abft=True, compute_dtype=jnp.float32)
    logits, cache, rep = jax.jit(
        lambda p, b: model.prefill(p, b, ctx, 32))(params, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(rep.total_errors()) == 0
    assert int(rep.gemm_checks) > 0
    assert int(rep.eb_checks) > 0


def test_vocab_padding_applied():
    cfg = small_arch("granite-moe-3b-a800m")
    assert cfg.vocab_padded % 256 == 0
    assert cfg.vocab_padded >= cfg.vocab


def test_dlrm_forward_and_abft():
    from repro.configs.dlrm import DlrmExtras
    from repro.models.dlrm import dlrm_forward, init_dlrm
    ex = DlrmExtras(n_dense=8, bottom_mlp=(32, 16), n_tables=4,
                    table_rows=128, emb_dim=16, pooling=5,
                    top_mlp=(32, 1), batch=3)
    key = jax.random.PRNGKey(3)
    params = values_of(init_dlrm(key, ex, quant=True, table_rows=128))
    dense = jax.random.normal(key, (3, 8))
    idx = jax.random.randint(key, (4, 3, 5), 0, 128)
    ctx = Ctx(quant=True, compute_dtype=jnp.float32)
    logit, rep = jax.jit(
        lambda p, d, i: dlrm_forward(p, d, i, ctx, ex))(params, dense, idx)
    assert logit.shape == (3,)
    assert np.all(np.isfinite(np.asarray(logit)))
    assert int(rep.total_errors()) == 0
    assert int(rep.eb_checks) > 0 and int(rep.gemm_checks) > 0
