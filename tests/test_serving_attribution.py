"""Per-request detection attribution + the serving obs export.

A mid-stream injection must blame exactly the requests resident in the
affected lane's slots when the flag fired — nobody in a clean pass, and
never requests that had already retired or not yet admitted.  The obs
export of a soak cell must agree with the artifact: every detected
injected fault has a detection FaultEvent with an op kind, a step, and
at least one attributed request id, and the Prometheus counters match
the cell's SoakMetrics numbers."""
import json

import pytest

from repro.configs import reduce_cfg
from repro.configs.registry import get_arch
from repro.obs import Observability, validate_event
from repro.protect import ProtectionPlan
from repro.serving import (FaultInjection, ServingEngine, TenantSpec,
                           chat_stream)

N_SLOTS = 2
MAX_PROMPT = 8
MAX_NEW = 4


@pytest.fixture(scope="module")
def engine():
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    tenants = [TenantSpec("t", ProtectionPlan.parse("*:policy=log",
                                                    name="t"))]
    eng = ServingEngine(cfg, tenants, n_slots=N_SLOTS,
                        max_prompt=MAX_PROMPT, max_new_tokens=MAX_NEW,
                        seed=0)
    eng.warmup()
    return eng


def _stream(n, seed=0):
    return chat_stream(n, tenants={"t": 1.0}, rate_rps=500.0, seed=seed,
                       mean_prompt=6, max_prompt=MAX_PROMPT,
                       mean_output=3, max_output=MAX_NEW)


def test_clean_run_has_no_suspects(engine):
    engine.reset_state()
    tel = engine.run(_stream(6, seed=1))
    s = tel.summary()
    assert s["faults"]["suspect_requests"] == 0
    assert all(r.detections == 0 and not r.suspect for r in tel.requests)
    assert s["per_tenant"]["t"]["suspect"] == 0
    assert s["per_tenant"]["t"]["detections"] == 0


def test_injection_attributes_to_resident_requests_exactly(engine):
    engine.reset_state()
    tel = engine.run(_stream(8, seed=3),
                     inject=[FaultInjection(step=2, victim="mlp.down",
                                            seed=0)])
    s = tel.summary()
    flagged = [ev for ev in tel.steps if ev.errors > 0]
    assert flagged
    resident = set()
    for ev in flagged:
        assert ev.slot_rids, "flagged step lost its slot occupancy"
        resident |= set(ev.slot_rids)
    by_rid = {r.rid: r for r in tel.requests}
    # exactly the resident requests are suspect — nobody else
    for rid, rec in by_rid.items():
        assert rec.suspect == (rid in resident), rid
        assert rec.detections == sum(
            1 for ev in flagged if rid in ev.slot_rids)
    assert s["faults"]["suspect_requests"] == len(resident)
    assert s["per_tenant"]["t"]["suspect"] == len(resident)
    # the injection record blames the first flagged step's residents
    (inj,) = s["faults"]["injections"]
    assert inj["detected"]
    assert tuple(inj["attributed_rids"]) == flagged[0].slot_rids
    assert len(inj["attributed_rids"]) >= 1


def test_attribution_is_idempotent(engine):
    engine.reset_state()
    tel = engine.run(_stream(8, seed=3),
                     inject=[FaultInjection(step=2, victim="mlp.down",
                                            seed=0)])
    tel.attribute_detections()
    first = {r.rid: r.detections for r in tel.requests}
    tel.summary()                      # finalize runs attribution again
    tel.attribute_detections()
    assert {r.rid: r.detections for r in tel.requests} == first


def test_engine_obs_detection_events_carry_rids(engine):
    engine.reset_state()
    obs = Observability.create()
    tel = engine.run(_stream(8, seed=3),
                     inject=[FaultInjection(step=2, victim="mlp.down",
                                            seed=0)],
                     obs=obs)
    detections = [e for e in obs.bus if e.kind == "detection"]
    injections = [e for e in obs.bus if e.kind == "injection"]
    assert detections and injections
    assert "mlp.down" in injections[0].op
    flagged = {ev.step: ev for ev in tel.steps if ev.errors > 0}
    for e in detections:
        assert e.op and e.step in flagged
        assert e.request_ids == flagged[e.step].slot_rids
        assert len(e.request_ids) >= 1
    # per-op error counters in the registry match the timeline totals
    totals = tel.fault_counters()
    errs = obs.registry.counter("repro_abft_errors_total")
    for op in {e.op for e in detections}:
        assert errs.value(op=op, source="serving.engine") == \
            totals[f"{op}_errors"]
    # spans and step counters cover every telemetry step
    steps = obs.registry.counter("repro_steps_total")
    assert steps.total() == len(tel.steps)
    assert len(obs.tracer.spans) == len(tel.steps)
    # obs must not leak into the next (clean) run
    engine.reset_state()
    engine.run(_stream(4, seed=4))
    assert steps.total() == len(tel.steps)


@pytest.fixture(scope="module")
def soak_cell_with_obs():
    from repro.serving.soak import SoakSpec, run_soak_cell, soak_plans

    spec = SoakSpec(name="serving_soak", arch="llama3.2-1b",
                    arrivals=("poisson",), n_requests=16, n_slots=2,
                    rate_rps=300.0, max_new_tokens=8, seed=0)
    (plan,) = soak_plans(spec)
    obs = Observability.create()
    cell = run_soak_cell(plan, obs=obs)
    return plan, cell, obs


def test_soak_cell_obs_counters_match_metrics(soak_cell_with_obs):
    plan, cell, obs = soak_cell_with_obs
    m = cell["metrics"]
    reg = obs.registry
    pairs = [("repro_injections_total", m["samples"]),
             ("repro_detections_total", m["detected"]),
             ("repro_escapes_total", m["escapes"]),
             ("repro_false_positives_total", m["false_positives"])]
    for name, want in pairs:
        assert reg.counter(name).value(cell=plan.cell_id) == want, name
    prom = reg.to_prometheus()
    assert f'repro_detections_total{{cell="{plan.cell_id}"}} ' \
        f'{m["detected"]}' in prom


def test_soak_cell_obs_every_detected_fault_has_attributed_event(
        soak_cell_with_obs, tmp_path):
    plan, cell, obs = soak_cell_with_obs
    m = cell["metrics"]
    assert m["detected"] >= 1, "soak cell did not detect its injection"
    detections = [e for e in obs.bus if e.kind == "detection"]
    inj_events = [e for e in obs.bus if e.kind == "injection"
                  and e.source == "serving.soak"]
    assert len(inj_events) == m["samples"]
    for inj in m["injections"]:
        if not inj["detected"]:
            continue
        hits = [e for e in detections
                if e.step == inj["detect_step"] and e.request_ids]
        assert hits, inj
        assert all(e.op for e in hits)
        assert set(inj["attributed_rids"]) <= {
            r for e in hits for r in e.request_ids}
    # the cell-summary event carries the detection rate as detector_value
    (cell_ev,) = [e for e in obs.bus if e.kind == "cell"]
    assert cell_ev.cell_id == plan.cell_id
    assert cell_ev.detector_value == pytest.approx(m["detection_rate"])
    # the JSONL export validates line by line
    paths = obs.write(str(tmp_path))
    for line in open(paths["events"]):
        validate_event(json.loads(line))
