"""ABFT for quantized GEMM (paper Alg. 1) — correctness + detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core import abft_gemm as ag
from repro.core.inject import flip_bit, random_bitflip, random_value


def _rand_ab(rng, m, k, n):
    a = rng.integers(0, 256, size=(m, k)).astype(np.uint8)
    b = rng.integers(-128, 128, size=(k, n)).astype(np.int8)
    return jnp.asarray(a), jnp.asarray(b)


# ------------------------- no-error behaviour -------------------------------

@pytest.mark.parametrize("m,k,n", [(1, 8, 8), (4, 64, 32), (13, 100, 77),
                                   (2, 800, 3200)])
def test_no_false_positives_and_correct_c(rng, m, k, n):
    a, b = _rand_ab(rng, m, k, n)
    out = ag.abft_qgemm(a, b)
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(out.c), want.astype(np.int32))
    assert int(out.err_count) == 0
    assert not bool(out.err_rows.any())


def test_fused_equals_unfused(rng):
    a, b = _rand_ab(rng, 8, 32, 16)
    f = ag.abft_qgemm(a, b)
    u = ag.abft_qgemm_unfused(a, b)
    np.testing.assert_array_equal(np.asarray(f.c), np.asarray(u.c))
    assert int(f.err_count) == int(u.err_count) == 0


def test_packed_layout_lane_aligned(rng):
    _, b = _rand_ab(rng, 1, 16, 40)
    packed = ag.pack_encoded_b(b)
    assert packed.shape == (16, 40 + ag.LANE)
    # lane 0 of the block holds the mod-127 checksum, other lanes zero
    cs = np.asarray(ag.encode_weight_checksum(b))
    np.testing.assert_array_equal(np.asarray(packed[:, 40]), cs)
    assert not np.asarray(packed[:, 41:]).any()


def test_rowsum_mod_no_overflow():
    # A row of C that would overflow a raw int32 row sum must not trip the
    # check (the paper's scheme adapted for LLM-sized n; DESIGN.md §3).
    m, k, n = 1, 4096, 28672
    a = jnp.full((m, k), 255, jnp.uint8)
    b = jnp.full((k, n), 127, jnp.int8)
    out = ag.abft_qgemm(a, b)
    assert int(out.err_count) == 0


# ------------------------- detection behaviour ------------------------------

def test_detects_bitflip_in_c_always(rng):
    """§IV-C2 model 1: 127 divides no power of two => 100% detection."""
    a, b = _rand_ab(rng, 6, 32, 24)
    base = ag.abft_qgemm(a, b)
    packed = ag.pack_encoded_b(b)
    c_full = jnp.matmul(a.astype(jnp.int32), packed.astype(jnp.int32))
    for bit in range(31):
        corrupted = flip_bit(c_full, jnp.asarray(5), jnp.asarray(bit))
        err_rows, cnt = ag.verify_rows(corrupted[:, :24], corrupted[:, 24])
        assert int(cnt) >= 1, f"bit {bit} escaped"
    assert int(base.err_count) == 0


def test_detects_weight_corruption_with_high_probability(rng):
    """§IV-C1: bit flip in B detected with prob >= 1-(3/256)^m; with m=8
    that is ~1-1e-15, so 200/200 trials must detect."""
    a, b = _rand_ab(rng, 8, 64, 48)
    checksum = ag.encode_weight_checksum(b)  # encoded BEFORE corruption
    detected = 0
    for s in range(200):
        key = jax.random.PRNGKey(s)
        b_bad = random_bitflip(key, b)
        if (b_bad == b).all():
            detected += 1  # flip may hit the same value? impossible for xor
            continue
        out = ag.abft_qgemm(a, b_bad, checksum=checksum)
        detected += int(out.err_count) > 0
    assert detected == 200


def test_analytic_probability_helpers():
    assert ag.detect_prob_b_bitflip(1) == pytest.approx(1 - 3 / 256)
    assert ag.detect_prob_b_random(1) == pytest.approx(1 - 1018 / 32640)
    assert ag.detect_prob_c_random() == pytest.approx(1 - 1 / 127)
    assert ag.detect_prob_b_bitflip(20) >= 0.9883


# ------------------------- property-based tests -----------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(1, 48), st.integers(1, 48),
       st.integers(0, 2 ** 31 - 1))
def test_prop_no_error_never_flags(m, k, n, seed):
    """Invariant: an uncorrupted integer GEMM NEVER raises a flag (the paper
    measured 0/2800 false positives; in the integer domain it is exact)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    out = ag.abft_qgemm(a, b)
    assert int(out.err_count) == 0


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 10), st.integers(2, 32), st.integers(2, 32),
       st.integers(0, 2 ** 31 - 1))
def test_prop_c_value_corruption_detected_unless_multiple_of_mod(m, k, n, seed):
    """A value replacement d in C is missed iff d ≡ 0 (mod 127) (§IV-C)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    packed = ag.pack_encoded_b(b)
    c_full = jnp.matmul(a.astype(jnp.int32), packed.astype(jnp.int32))
    i = rng.integers(0, m)
    j = rng.integers(0, n)
    delta = int(rng.integers(1, 2 ** 20))
    corrupted = c_full.at[i, j].add(delta)
    _, cnt = ag.verify_rows(corrupted[:, :n], corrupted[:, n])
    if delta % 127 == 0:
        assert int(cnt) == 0   # the analytically-unavoidable escape
    else:
        assert int(cnt) == 1


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_prop_row_localization(seed):
    """A single corrupted element flags exactly its own row (enables
    row-granular recompute)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 256, size=(6, 16)), jnp.uint8)
    b = jnp.asarray(rng.integers(-128, 128, size=(16, 10)), jnp.int8)
    packed = ag.pack_encoded_b(b)
    c_full = jnp.matmul(a.astype(jnp.int32), packed.astype(jnp.int32))
    i = int(rng.integers(0, 6))
    corrupted = c_full.at[i, int(rng.integers(0, 10))].add(3)
    err_rows, _ = ag.verify_rows(corrupted[:, :10], corrupted[:, 10])
    assert bool(err_rows[i])
    assert int(err_rows.sum()) == 1


# ------------------------- weight-flip correction ---------------------------
# (B carries two encodings: the packed mod-127 row checksum plus exact
# int32 column sums.  A single flipped weight is localized to (k0, j0)
# with its exact delta and C repaired without recomputing anything.)

def _weight_flip_case(rng, m=4, k=16, n=12):
    a, b = _rand_ab(rng, m, k, n)
    packed = ag.pack_encoded_b(b)
    colsum = ag.encode_weight_colsum(b)
    want = (np.asarray(a, np.int64) @ np.asarray(b, np.int64)).astype(
        np.int32)
    return a, packed, colsum, want


def _c_of(a, packed, n):
    return jnp.asarray(np.asarray(a, np.int64)
                       @ np.asarray(packed)[:, :n].astype(np.int64),
                       jnp.int32)


def test_correct_weight_flip_repairs_payload_flip(rng):
    a, packed, colsum, want = _weight_flip_case(rng)
    bad = np.asarray(packed).copy()
    bad[5, 3] ^= np.int8(0x04)
    bad = jnp.asarray(bad)
    fixed, applied = ag.correct_weight_flip(_c_of(a, bad, 12), a, bad,
                                            colsum)
    assert bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), want)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 7))
def test_correct_weight_flip_any_bit_property(seed, bit):
    """Every single-bit payload flip is repaired exactly: the int8 delta
    (+-2^b) is never 0 mod 127, so the row residue always flags k0."""
    rng = np.random.default_rng(seed)
    a, packed, colsum, want = _weight_flip_case(rng, m=3, k=12, n=8)
    bad = np.asarray(packed).copy()
    k0, j0 = int(rng.integers(12)), int(rng.integers(8))
    bad[k0, j0] ^= np.int8(-128) if bit == 7 else np.int8(1 << bit)
    bad = jnp.asarray(bad)
    fixed, applied = ag.correct_weight_flip(_c_of(a, bad, 8), a, bad,
                                            colsum)
    assert bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), want)


def test_correct_weight_flip_declines_outside_single_error_model(rng):
    a, packed, colsum, want = _weight_flip_case(rng, m=2, k=10, n=6)
    # clean B: nothing flagged, C untouched
    c = _c_of(a, packed, 6)
    fixed, applied = ag.correct_weight_flip(c, a, packed, colsum)
    assert not bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(c))
    # two flips in different rows/columns: not the single-error model
    two = np.asarray(packed).copy()
    two[1, 2] ^= np.int8(1)
    two[4, 5] ^= np.int8(2)
    two = jnp.asarray(two)
    c2 = _c_of(a, two, 6)
    fixed, applied = ag.correct_weight_flip(c2, a, two, colsum)
    assert not bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(c2))
    # a flip in the checksum lane flags a row but no column: declined,
    # and the (clean-payload) product stays untouched
    lane = np.asarray(packed).copy()
    lane[3, 6] ^= np.int8(1)
    lane = jnp.asarray(lane)
    fixed, applied = ag.correct_weight_flip(c, a, lane, colsum)
    assert not bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), want)
