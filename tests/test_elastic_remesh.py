"""Elastic re-mesh: survive losing devices, restore the checkpoint onto a
smaller mesh, keep training — the 1000-node failure drill in miniature."""
import os
import subprocess
import sys
import textwrap


def test_remesh_restore_subprocess(tmp_path):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, load_checkpoint
        from repro.runtime import plan_remesh
        from repro.runtime.elastic import make_mesh_from_plan

        # "before": 4x2 mesh, params sharded over model
        mesh0 = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                     ("data", "model"))
        w = jnp.arange(64.0).reshape(8, 8)
        sh0 = NamedSharding(mesh0, P(None, "model"))
        state = {"w": jax.device_put(w, sh0),
                 "step": jnp.asarray(5, jnp.int32)}
        ckdir = tempfile.mkdtemp()
        save_checkpoint(ckdir, 5, state)

        # "failure": 2 devices lost -> 6 survive; model_parallel stays 2
        plan = plan_remesh(6, model_parallel=2)
        assert plan.new_shape == (3, 2), plan
        mesh1 = make_mesh_from_plan(plan)
        sh1 = NamedSharding(mesh1, P(None, "model"))
        restored = load_checkpoint(ckdir, 5, jax.device_get(state),
                                   {"w": sh1, "step":
                                    NamedSharding(mesh1, P())})
        np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding.mesh.devices.size == 6
        # one more step on the shrunken mesh proves liveness
        y = jax.jit(lambda s: {"w": s["w"] * 2.0,
                               "step": s["step"] + 1})(restored)
        assert int(y["step"]) == 6
        print("OK")
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=str(tmp_path.parent)
                       if False else os.path.dirname(
                           os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-2000:]
