"""Quickstart: the paper's two protected operators in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. quantized GEMM with fused ABFT (Algorithm 1) — encode once, verify
   every call, catch an injected bit flip;
2. quantized EmbeddingBag with ABFT (Algorithm 2) — row-sum invariant;
3. the detect -> recompute policy wrapper;
4. the same machinery inside a full transformer layer (int8 serving path).
"""
import jax
import jax.numpy as jnp

from repro.core import abft_gemm as ag
from repro.core import abft_embedding as ae
from repro.core.inject import random_bitflip
from repro.core.policy import with_recompute

print("=" * 64)
print("1) ABFT for quantized GEMM (paper Algorithm 1)")
print("=" * 64)

key = jax.random.key(0)
ka, kb, kf = jax.random.split(key, 3)
m, k, n = 20, 512, 1024
a_q = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)      # activations
b_q = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)    # weights

# encode ONCE at model load (amortized, §IV-A1); mod-127 keeps it int8
checksum = ag.encode_weight_checksum(b_q)
print(f"weight checksum: {checksum.shape} {checksum.dtype} (mod {ag.MOD})")

out = ag.abft_qgemm(a_q, b_q, checksum=checksum)
print(f"clean GEMM:    C={out.c.shape} int32, errors={int(out.err_count)}")

b_bad = random_bitflip(kf, b_q)                               # memory fault
out_bad = ag.abft_qgemm(a_q, b_bad, checksum=checksum)
print(f"after bitflip: errors={int(out_bad.err_count)} "
      f"(corrupted rows flagged: {int(out_bad.err_rows.sum())})")

print()
print("=" * 64)
print("2) ABFT for quantized EmbeddingBag (paper Algorithm 2)")
print("=" * 64)

rows, d, pool, bags = 10_000, 64, 100, 10
kt, ka2, kb2, ki = jax.random.split(jax.random.key(1), 4)
table = jax.random.randint(kt, (rows, d), -128, 128, jnp.int8)
alphas = jax.random.uniform(ka2, (rows,), jnp.float32, 1e-3, 2e-3)
betas = jax.random.uniform(kb2, (rows,), jnp.float32, -1e-2, 1e-2)
rowsums = ae.table_rowsums(table)        # C_T: precomputed, unscaled int32
idx = jax.random.randint(ki, (bags, pool), 0, rows, jnp.int32)

out = ae.abft_embedding_bag(table, alphas, betas, idx, rowsums)
print(f"clean EB:      R={out.r.shape} f32, errors={int(out.err_count)}")

table_bad = table.at[int(idx[0, 0]), 3].add(64)   # high-bit corruption
out_bad = ae.abft_embedding_bag(table_bad, alphas, betas, idx, rowsums)
print(f"after corrupt: errors={int(out_bad.err_count)} "
      f"(bags flagged: {out_bad.err_bags.astype(int).tolist()})")

print()
print("=" * 64)
print("3) detect -> recompute policy (paper §I: errors rarely strike twice)")
print("=" * 64)

calls = {"n": 0}


def flaky_gemm():
    calls["n"] += 1
    b_use = b_bad if calls["n"] == 1 else b_q     # transient fault
    o = ag.abft_qgemm(a_q, b_use, checksum=checksum)
    return o.c, o.err_count


# NOTE: with_recompute is lax.cond-based for in-graph use; here we drive it
# eagerly so the python closure can model a *transient* fault.
c1, err1 = flaky_gemm()
if int(err1) > 0:
    c2, err2 = flaky_gemm()
    print(f"first pass errors={int(err1)} -> recomputed, "
          f"errors={int(err2)} (policy cleared the fault)")

print()
print("=" * 64)
print("4) the same, inside a transformer (int8+ABFT serving path)")
print("=" * 64)

from repro.configs.registry import get_arch          # noqa: E402
from repro.configs.reduce import reduce_cfg          # noqa: E402
from repro.layers.common import Ctx                  # noqa: E402
from repro.models.base import build_model            # noqa: E402
from repro.sharding import values_of                 # noqa: E402

cfg = reduce_cfg(get_arch("llama3.2-1b"))
model = build_model(cfg, max_pos=128)
params = values_of(model.init(jax.random.key(2), quant=True))
ctx = Ctx(quant=True, abft=True)
tokens = jax.random.randint(jax.random.key(3), (2, 16), 0, cfg.vocab,
                            jnp.int32)
logits, cache, report = jax.jit(
    lambda p, t: model.prefill(p, {"tokens": t}, ctx, cache_len=32)
)(params, tokens)
print(f"prefill logits {logits.shape}; ABFT: "
      f"{int(report.gemm_checks)} GEMM checks, "
      f"{int(report.gemm_errors)} errors, "
      f"{int(report.eb_checks)} EB checks")
print("\nquickstart OK")
