"""Quickstart: the paper's protected operators behind one API, in five
minutes.

    PYTHONPATH=src python examples/quickstart.py

1. the ProtectedOp protocol: encode once, verify every call — quantized
   GEMM (Algorithm 1) catches an injected bit flip;
2. quantized EmbeddingBag (Algorithm 2) through the same protocol;
3. protection plans: per-op-pattern policy/threshold rules from a string;
4. ``protect(apply_fn, plan)`` on a full transformer — flipping EB
   protection off or switching policy to ``recompute`` is a plan edit,
   not a model edit.
"""
import jax
import jax.numpy as jnp

from repro.core.inject import random_bitflip
from repro.protect import ProtectionPlan, get_op, protect, protected_call
from repro.protect.plan import ResolvedRule

print("=" * 64)
print("1) ProtectedOp: quantized GEMM (paper Algorithm 1)")
print("=" * 64)

qgemm = get_op("qgemm")
key = jax.random.key(0)
ka, kb, kf = jax.random.split(key, 3)
m, k, n = 20, 512, 1024
a_q = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)      # activations
b_q = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)    # weights

# encode ONCE at model load (amortized, §IV-A1): B' = [B | checksum block]
b_packed = qgemm.encode(b_q)
print(f"encoded weight: {b_q.shape} -> packed {b_packed.shape} int8")

c, check = qgemm(b_packed, a_q)
print(f"clean GEMM:    C={c.shape} int32, errors={int(check.err_count)}")

b_bad = random_bitflip(kf, b_q)                               # memory fault
b_bad_packed = jnp.concatenate([b_bad, b_packed[:, n:]], axis=1)
c_bad, check_bad = qgemm(b_bad_packed, a_q)
print(f"after bitflip: errors={int(check_bad.err_count)} "
      f"(corrupted rows flagged: {int(check_bad.err_mask.sum())})")

print()
print("=" * 64)
print("2) ProtectedOp: quantized EmbeddingBag (paper Algorithm 2)")
print("=" * 64)

eb = get_op("embedding_bag")
rows, d, pool, bags = 10_000, 64, 100, 10
kt, ka2, kb2, ki = jax.random.split(jax.random.key(1), 4)
table = jax.random.randint(kt, (rows, d), -128, 128, jnp.int8)
alphas = jax.random.uniform(ka2, (rows,), jnp.float32, 1e-3, 2e-3)
betas = jax.random.uniform(kb2, (rows,), jnp.float32, -1e-2, 1e-2)
enc = eb.encode((table, alphas, betas))       # precomputes C_T row sums
idx = jax.random.randint(ki, (bags, pool), 0, rows, jnp.int32)

r, check = eb(enc, idx)
print(f"clean EB:      R={r.shape} f32, errors={int(check.err_count)}")

table_bad = table.at[int(idx[0, 0]), 3].add(64)   # high-bit corruption
r_bad, check_bad = eb((table_bad,) + enc[1:], idx)
print(f"after corrupt: errors={int(check_bad.err_count)} "
      f"(bags flagged: {check_bad.err_mask.astype(int).tolist()})")

print()
print("=" * 64)
print("3) protection plans: policy per op pattern, from a string")
print("=" * 64)

plan = ProtectionPlan.parse(
    "*:policy=log,qgemm:policy=recompute:retries=1,embedding_bag:off")
print("plan:", plan.describe())
print("  qgemm rule:", plan.resolve("qgemm", "mlp.up"))
print("  EB rule:   ", plan.resolve("embedding_bag", "tables"))

# the recompute policy re-runs the op under lax.cond when errors surface
c2, report = protected_call("qgemm", b_bad_packed, a_q,
                            rule=ResolvedRule(policy="recompute"))
print(f"recompute policy on the corrupted GEMM: "
      f"errors={int(report.errors['qgemm'])}, "
      f"retries={int(report.retries)} (deterministic sim: fault persists)")

print()
print("=" * 64)
print("4) protect(apply_fn, plan): a full transformer, plan-selected")
print("=" * 64)

from repro.configs.registry import get_arch          # noqa: E402
from repro.configs.reduce import reduce_cfg          # noqa: E402
from repro.models.base import build_model            # noqa: E402
from repro.sharding import values_of                 # noqa: E402

cfg = reduce_cfg(get_arch("llama3.2-1b"))
model = build_model(cfg, max_pos=128)
params = values_of(model.init(jax.random.key(2), quant=True))
tokens = jax.random.randint(jax.random.key(3), (2, 16), 0, cfg.vocab,
                            jnp.int32)

for plan_str in ("*:policy=log", "embedding_bag:off"):
    plan = ProtectionPlan.parse("*:policy=log," + plan_str)
    prefill = protect(model.prefill, plan)
    (logits, cache), report = jax.jit(
        lambda p, t, pf=prefill: pf(p, {"tokens": t}, cache_len=32)
    )(params, tokens)
    print(f"plan '{plan_str}': logits {logits.shape}; "
          f"{int(report.gemm_checks)} GEMM checks, "
          f"{int(report.eb_checks)} EB checks, "
          f"{int(report.total_errors())} errors")

print("\nquickstart OK")
