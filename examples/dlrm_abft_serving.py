"""The paper's own workload end to end: int8 DLRM inference under ABFT.

    PYTHONPATH=src python examples/dlrm_abft_serving.py

Bottom MLP -> 26 quantized EmbeddingBags -> pairwise interaction -> top MLP,
every GEMM running Algorithm 1 and every bag lookup Algorithm 2.  A fault
campaign flips random bits in weights / tables mid-serving and reports the
detect -> recompute behaviour and CTR-score impact.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.dlrm import EXTRAS
from repro.configs.registry import get_arch

from repro.data import make_dataset
from repro.models.dlrm import dlrm_forward, init_dlrm
from repro.protect import default_plan, protect
from repro.sharding import values_of

# scaled-down tables (CPU example; the benchmark suite runs 4M rows)
ex = dataclasses.replace(EXTRAS, table_rows=50_000)

params = values_of(init_dlrm(jax.random.key(0), ex, quant=True,
                             table_rows=ex.table_rows))
n_bytes = sum(x.size * x.dtype.itemsize
              for x in jax.tree.leaves(params))
print(f"DLRM (paper §VI config, tables scaled to {ex.table_rows} rows): "
      f"{n_bytes/2**20:.0f} MiB int8 parameters")

shape = ShapeConfig("serve", "train", 1, ex.batch)
ds = make_dataset(get_arch("dlrm"), shape)
# protection selected purely by plan: every GEMM Alg. 1, every bag Alg. 2
fwd_p = protect(lambda p, d, i, ctx: dlrm_forward(p, d, i, ctx, ex),
                default_plan())
fwd = jax.jit(lambda p, d, i: fwd_p(p, d, i))

batch = ds.batch_at(0, table_rows=ex.table_rows)
scores, report = fwd(params, jnp.asarray(batch["dense"]),
                     jnp.asarray(batch["bags"]))
print(f"\nclean batch:  scores[0:4]={np.asarray(scores[:4]).round(3)}")
print(f"  ABFT: {int(report.gemm_checks)} GEMM checks "
      f"+ {int(report.eb_checks)} EB checks, "
      f"{int(report.total_errors())} errors")

# ---- fault campaign --------------------------------------------------------
# Faults target state the request actually touches: MLP weights (GEMM
# ABFT territory) and table rows the bags index (EB ABFT territory).  A
# flip in one of 50k untouched rows is invisible by construction — the
# paper's coverage is "data participating in the computation" (§IV-C).
print("\nfault campaign: 8 requests, a bit flip in *accessed* state")
clean_params = params
rng = np.random.default_rng(0)
detected = 0
for i in range(8):
    batch = ds.batch_at(i + 1, table_rows=ex.table_rows)
    dense, bags = jnp.asarray(batch["dense"]), jnp.asarray(batch["bags"])
    bad_params = jax.tree.map(lambda x: x, clean_params)
    if i % 2 == 0:   # GEMM weight fault (packed int8, checksum encoded)
        stack = rng.choice(["bottom", "top"])
        li = rng.integers(len(clean_params[stack]))
        wp = clean_params[stack][li]["w_packed"]
        r_, c_ = rng.integers(wp.shape[0]), rng.integers(wp.shape[1] - 128)
        bad = wp.at[r_, c_].set(wp[r_, c_] ^ np.int8(0x20))
        bad_params[stack][li]["w_packed"] = bad
        where = f"{stack}[{li}].w_packed[{r_},{c_}]"
    else:            # EB fault in a row this request pools
        t_ = rng.integers(ex.n_tables)
        valid = np.asarray(bags[t_]).ravel()
        row = int(rng.choice(valid[valid >= 0]))
        col = int(rng.integers(ex.emb_dim))
        tb = clean_params["tables"]["table"]
        bad = tb.at[t_, row, col].set(tb[t_, row, col] ^ np.int8(0x40))
        bad_params["tables"]["table"] = bad
        where = f"tables[{t_}].row[{row}][{col}]"
    scores_bad, rep = fwd(bad_params, dense, bags)
    errs = int(rep.total_errors())
    scores_ref, _ = fwd(clean_params, dense, bags)
    drift = float(jnp.max(jnp.abs(scores_bad - scores_ref)))
    if errs:
        detected += 1
        scores_fix, rep2 = fwd(clean_params, dense, bags)
        status = (f"DETECTED ({errs} ops) -> recomputed, "
                  f"errors={int(rep2.total_errors())}")
    else:
        status = f"undetected (score drift {drift:.2e})"
    print(f"  req {i}: {where:32s} {status}")
print(f"\ndetected {detected}/8 injected faults in accessed state")
assert detected >= 6, "ABFT detection below expectation"
print("dlrm_abft_serving OK")
