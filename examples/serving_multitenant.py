"""Multi-tenant protected serving under a mid-traffic bit flip.

Two traffic classes share one engine with different protection plans:

* ``premium`` — detect→recompute on every op, checksummed int8 KV cache,
  tight EmbeddingBag threshold (the V-ABFT per-tenant-thresholds idea);
* ``besteffort`` — log-only protection, loose threshold, bf16 cache.

A bursty request stream drives the continuous batcher; halfway through, a
bit flips in the attention query projection.  The telemetry timeline then
shows — in one place — the online detection, the recompute retries the
premium lane paid, and each tenant's TTFT/per-token SLO percentiles.

    PYTHONPATH=src python examples/serving_multitenant.py
"""
from repro.configs import reduce_cfg
from repro.configs.registry import get_arch
from repro.protect import ProtectionPlan
from repro.serving import (FaultInjection, ServingEngine, TenantSpec,
                           chat_stream)


def main():
    cfg = reduce_cfg(get_arch("llama3.2-1b"))

    tenants = [
        TenantSpec("premium", ProtectionPlan.parse(
            "*:policy=recompute:retries=2,kv_cache:on,"
            "embedding_bag:rel_bound=1e-5", name="premium")),
        TenantSpec("besteffort", ProtectionPlan.parse(
            "*:policy=log,embedding_bag:rel_bound=1e-3",
            name="besteffort"), weight=2.0),
    ]
    engine = ServingEngine(cfg, tenants, n_slots=4, max_prompt=32,
                           max_new_tokens=12, seed=0)
    print(f"{len(engine.lanes)} plan lanes:")
    for lane in engine.lanes:
        print(f"  {lane.key}: tenants={sorted(lane.tenants)}")

    stream = chat_stream(
        60, tenants={"premium": 1.0, "besteffort": 2.0},
        rate_rps=400.0, arrival="bursty", seed=0,
        mean_prompt=20, max_prompt=32, mean_output=8, max_output=12)

    telemetry = engine.run(
        stream, inject=[FaultInjection(step=10, victim="attn.wq")])
    s = telemetry.summary()

    print(f"\nserved {s['requests']} requests in {s['span_s']:.2f}s "
          f"({s['throughput_tok_s']:.0f} tok/s), "
          f"queue depth max {s['queue_depth_max']}")
    for name, ts in s["per_tenant"].items():
        print(f"  {name:>10}: n={ts['requests']:<3} "
              f"TTFT p50/p95/p99 = {ts['ttft_ms']['p50']:.1f}/"
              f"{ts['ttft_ms']['p95']:.1f}/{ts['ttft_ms']['p99']:.1f} ms"
              f"  per-token p99 = {ts['per_token_ms']['p99']:.2f} ms")

    f = s["faults"]
    print(f"\nfault counters: "
          f"{ {k: v for k, v in f['counters'].items() if v} }")
    for inj in f["injections"]:
        state = (f"DETECTED after {inj['latency_steps']} step(s), "
                 f"{1e3 * inj['latency_s']:.2f} ms"
                 if inj["detected"] else "not detected (masked)")
        print(f"injected {inj['victim']} at step {inj['step']}: {state}")
    retries = f["counters"].get("retries", 0)
    if retries:
        print(f"premium lane recompute retries: {retries} "
              f"(the per-tenant policy at work)")


if __name__ == "__main__":
    main()
