"""Rebuild a metrics dashboard from an exported fault-event stream.

Every obs-instrumented run (``--obs-dir`` on the campaign CLI or
``launch/serve.py``, or ``Observability.write`` in code) drops an
``obs_events.jsonl`` — one validated JSON object per fault event.  That
file is the durable record: ``repro.obs.replay`` folds it back into a
fresh ``MetricsRegistry``, so Prometheus text (or the JSON export) can
be regenerated for dashboards without re-running the experiment.  When
the run carried a live ``Monitor``, the stream also holds ``alert`` and
``health`` events, so the alert history and per-tenant health timeline
below need nothing beyond the JSONL either.

    PYTHONPATH=src python examples/obs_dashboard.py [obs_events.jsonl]

With no argument, runs a small live-traffic soak cell first (with the
detection-health monitor attached) to produce an event stream, then
replays it.
"""
import sys
import tempfile

from repro.obs import EventBus, Monitor, Observability, replay


def make_events() -> str:
    """Run one quick serving-soak cell with obs and export its events."""
    from repro.serving.soak import quick_soak_spec, run_soak_cell, soak_plans

    spec = quick_soak_spec(seed=0, n_requests=24)
    plan = soak_plans(spec)[0]
    print(f"running soak cell {plan.cell_id} "
          f"(inject at steps {plan.inject_steps}) ...")
    obs = Observability.create()
    monitor = Monitor()
    cell = run_soak_cell(plan, obs=obs, monitor=monitor)
    m = cell["metrics"]
    ms = monitor.summary()
    print(f"  detected {m['detected']}/{m['samples']} injections, "
          f"fp_rate {m['fp_rate']:.3f}")
    print(f"  monitor: {ms['ticks']} tick(s), {ms['alerts_fired']} "
          f"alert(s) fired")
    out_dir = tempfile.mkdtemp(prefix="repro_obs_")
    return obs.write(out_dir)["events"]


def alert_history(bus: EventBus) -> None:
    """Chronological firing/resolution log, rebuilt from alert events."""
    alerts = [ev for ev in bus if ev.kind == "alert"]
    if not alerts:
        return
    print("\n--- Alert history " + "-" * 49)
    for ev in alerts:
        a = ev.attrs
        print(f"  t={ev.t_s:8.3f}s  {a.get('state', 'firing'):8s} "
              f"{a.get('rule', '?')} [{a.get('severity', '?')}] "
              f"{a.get('scope', '?')}")


def health_timelines(bus: EventBus) -> None:
    """Per-scope health transitions (monitor) and engine responses."""
    moves = [ev for ev in bus if ev.kind == "health"
             and ev.source == "obs.monitor"]
    actions = [ev for ev in bus if ev.kind == "health"
               and ev.source == "serving.engine"]
    if not moves and not actions:
        return
    print("\n--- Health timelines " + "-" * 46)
    by_scope: dict = {}
    for ev in moves:
        by_scope.setdefault(ev.attrs.get("scope", "?"), []).append(ev)
    for scope in sorted(by_scope):
        hops = by_scope[scope]
        path = hops[0].attrs.get("from", "healthy")
        for ev in hops:
            path += f" -> {ev.attrs.get('to', '?')}"
        print(f"  {scope}: {path}")
        for ev in hops:
            print(f"    t={ev.t_s:8.3f}s tick={ev.attrs.get('tick')} "
                  f"{ev.attrs.get('from')} -> {ev.attrs.get('to')} "
                  f"({ev.attrs.get('reason', '')})")
    for ev in actions:
        print(f"  engine action t={ev.t_s:8.3f}s: "
              f"{ev.attrs.get('action', '?')} "
              f"{ev.attrs.get('scope', ev.attrs.get('tenant', ''))}")


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else make_events()

    bus = EventBus.from_jsonl(path)
    print(f"\n{len(bus)} events from {path}")
    by_kind: dict = {}
    for ev in bus:
        by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
    print("  " + "  ".join(f"{k}={v}" for k, v in sorted(by_kind.items())))
    print("  residual errors by op (FaultReport-comparable): "
          f"{bus.counters()}")

    # per-request attribution lives on the detection events
    touched = sorted({rid for ev in bus if ev.kind == "detection"
                      for rid in ev.request_ids})
    if touched:
        print(f"  requests resident during flagged steps: {touched}")

    alert_history(bus)
    health_timelines(bus)

    registry = replay(bus)
    print("\n--- Prometheus exposition (replayed) " + "-" * 30)
    print(registry.to_prometheus())
    return 0


if __name__ == "__main__":
    sys.exit(main())
