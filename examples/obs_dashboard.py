"""Rebuild a metrics dashboard from an exported fault-event stream.

Every obs-instrumented run (``--obs-dir`` on the campaign CLI or
``launch/serve.py``, or ``Observability.write`` in code) drops an
``obs_events.jsonl`` — one validated JSON object per fault event.  That
file is the durable record: ``repro.obs.replay`` folds it back into a
fresh ``MetricsRegistry``, so Prometheus text (or the JSON export) can
be regenerated for dashboards without re-running the experiment.

    PYTHONPATH=src python examples/obs_dashboard.py [obs_events.jsonl]

With no argument, runs a small live-traffic soak cell first to produce
an event stream, then replays it.
"""
import sys
import tempfile

from repro.obs import EventBus, Observability, replay


def make_events() -> str:
    """Run one quick serving-soak cell with obs and export its events."""
    from repro.serving.soak import quick_soak_spec, run_soak_cell, soak_plans

    spec = quick_soak_spec(seed=0, n_requests=24)
    plan = soak_plans(spec)[0]
    print(f"running soak cell {plan.cell_id} "
          f"(inject at steps {plan.inject_steps}) ...")
    obs = Observability.create()
    cell = run_soak_cell(plan, obs=obs)
    m = cell["metrics"]
    print(f"  detected {m['detected']}/{m['samples']} injections, "
          f"fp_rate {m['fp_rate']:.3f}")
    out_dir = tempfile.mkdtemp(prefix="repro_obs_")
    return obs.write(out_dir)["events"]


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else make_events()

    bus = EventBus.from_jsonl(path)
    print(f"\n{len(bus)} events from {path}")
    by_kind: dict = {}
    for ev in bus:
        by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
    print("  " + "  ".join(f"{k}={v}" for k, v in sorted(by_kind.items())))
    print("  residual errors by op (FaultReport-comparable): "
          f"{bus.counters()}")

    # per-request attribution lives on the detection events
    touched = sorted({rid for ev in bus if ev.kind == "detection"
                      for rid in ev.request_ids})
    if touched:
        print(f"  requests resident during flagged steps: {touched}")

    registry = replay(bus)
    print("\n--- Prometheus exposition (replayed) " + "-" * 30)
    print(registry.to_prometheus())
    return 0


if __name__ == "__main__":
    sys.exit(main())
