"""Custom resilience campaign: author a spec, run it, read the artifact.

    PYTHONPATH=src python examples/campaign_custom.py

Sweeps the significant-bit-band fault model (Ma et al. 2023) over the
serving GEMM and the quantized KV cache — including the float32 scale
cells whose escape rate quantifies the checksum's known coverage gap —
then registers a CUSTOM target on the fly: bit flips striking the
EmbeddingBag *rowsum checksum itself* (does corrupting the detector's own
metadata raise flags? it should: Eq. 5 breaks from either side).
"""
import jax
import jax.numpy as jnp

from repro.campaign import (CampaignSpec, InjectableTarget, markdown_table,
                            register_target, run_campaign)
from repro.campaign.targets import apply_fault
from repro.protect import get_op

EB = get_op("embedding_bag")

# ---------------------------------------------------------------------- #
# 1. a custom injectable target: corrupt C_T, the checksum sidecar       #
# ---------------------------------------------------------------------- #


def _build(plan, key):
    rows, dim, bags, pool = plan.shape
    kt, ka, kb = jax.random.split(key, 3)
    table = jax.random.randint(kt, (rows, dim), -128, 128, jnp.int8)
    return {
        "table": table,
        "alphas": jax.random.uniform(ka, (rows,), jnp.float32, 1e-2, 2e-2),
        "betas": jax.random.uniform(kb, (rows,), jnp.float32, 0.3, 0.7),
        "rowsums": EB.encode((table, None, None))[-1],
    }


def _trial(state, plan, key):
    rows, dim, bags, pool = plan.shape
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (bags, pool), 0, rows, jnp.int32)
    rs_bad = apply_fault(k2, state["rowsums"], plan)
    _, check = EB((state["table"], state["alphas"], state["betas"],
                   rs_bad), idx)
    # corrupted ground truth: the flip must hit a rowsum a bag gathers
    touched = jnp.isin(jnp.arange(rows), idx.reshape(-1))
    return check.err_count > 0, jnp.any((rs_bad != state["rowsums"])
                                        & touched)


def _clean(state, plan, key):
    rows, dim, bags, pool = plan.shape
    idx = jax.random.randint(key, (bags, pool), 0, rows, jnp.int32)
    _, check = EB((state["table"], state["alphas"], state["betas"],
                   state["rowsums"]), idx)
    return check.err_count > 0


register_target(InjectableTarget(
    name="eb_rowsum_meta",
    build=_build, trial=_trial, clean=_clean,
    default_shapes=((2_000, 64, 8, 50),), shape_arity=4,
    dtypes=("int32",)))

# ---------------------------------------------------------------------- #
# 2. the campaign: built-ins + the custom target in one sweep            #
# ---------------------------------------------------------------------- #

specs = [
    CampaignSpec(
        name="significant-gemm",
        targets=("gemm_packed",),
        bit_bands=("significant",),
        shapes=((20, 256, 512),),
        samples=300, seed=1, measure_overhead=True),
    CampaignSpec(
        name="kv-including-scale-gap",
        targets=("kv_cache",),
        bit_bands=("all",),
        dtypes=("int8", "float32"),   # float32 = the UNPROTECTED scales
        samples=200, seed=1),
    CampaignSpec(
        name="checksum-metadata",
        targets=("eb_rowsum_meta",),
        dtypes=("int32",),
        samples=150, seed=1),
]

if __name__ == "__main__":
    result = run_campaign("custom-example", specs, out_dir=".",
                          verbose=print)
    print()
    print(markdown_table(result))
