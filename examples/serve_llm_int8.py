"""Batched int8 LLM serving with ABFT — prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_llm_int8.py [--arch qwen3-8b]

Drives the public serving API the way `launch/serve.py` does in
production, on a smoke-reduced config: a batch of prompts is prefilled,
then decoded token by token; at step 6 a bit is flipped in a packed int8
weight and the per-step ABFT report shows detection from that step on
(a memory fault in B persists until the weight is re-fetched — §IV-A1).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduce import reduce_cfg          # noqa: E402
from repro.configs.registry import get_arch          # noqa: E402
from repro.core.inject import flip_bit_in_leaf       # noqa: E402
from repro.launch.steps import (make_decode_step,    # noqa: E402
                                make_prefill_step)
from repro.layers.common import Ctx                  # noqa: E402
from repro.models.base import build_model            # noqa: E402
from repro.sharding import values_of                 # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--tokens", type=int, default=12)
args = ap.parse_args()

cfg = reduce_cfg(get_arch(args.arch))
cache_len = args.prompt_len + args.tokens + cfg.meta_tokens + 4
model = build_model(cfg, max_pos=cache_len + 8)
ctx = Ctx(quant=True, abft=True)

params = values_of(model.init(jax.random.key(0), quant=True))
prefill = jax.jit(make_prefill_step(model, ctx, cache_len=cache_len))
decode = jax.jit(make_decode_step(model, ctx), donate_argnums=(1,))

rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
if cfg.family == "vlm":
    batch["patches"] = jnp.asarray(
        rng.standard_normal((args.batch, cfg.n_patches, cfg.patch_dim)),
        jnp.float32)
if cfg.family == "encdec":
    batch["frames"] = jnp.asarray(
        rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)),
        jnp.float32)

tok, cache, metrics = prefill(params, batch)
print(f"{args.arch} (smoke-reduced, int8+ABFT): prefill of "
      f"{args.batch}x{args.prompt_len} — "
      f"{int(metrics['abft/gemm_checks'])} GEMM checks, "
      f"{int(metrics['abft/gemm_errors'])} errors")

pos = jnp.full((args.batch,), args.prompt_len + cfg.meta_tokens, jnp.int32)
if cfg.family == "vlm":
    pos = pos + cfg.n_patches
seqs = [np.asarray(tok)]
for step in range(args.tokens):
    if step == 6:
        params, where = flip_bit_in_leaf(params, jax.random.key(99))
        print(f"  >>> bit flip injected into {where}")
    tok, cache, metrics = decode(params, cache, tok, pos)
    errs = int(metrics["abft/gemm_errors"]) + int(metrics["abft/eb_errors"])
    flag = f"  ABFT errors={errs}" if errs else ""
    print(f"  decode step {step:2d}: tokens={np.asarray(tok)}{flag}")
    seqs.append(np.asarray(tok))
    pos = pos + 1

print("generated:", np.stack(seqs, 1).tolist()[0])
print("serve_llm_int8 OK")
