"""End-to-end driver: train a ~100M-param LM with the fault-tolerant loop.

    PYTHONPATH=src python examples/train_fault_tolerant.py            # quick
    PYTHONPATH=src python examples/train_fault_tolerant.py --full     # ~100M

Demonstrates the full production stack on one host:
  * seeded synthetic data pipeline (restart-safe: batch = f(seed, step)),
  * jitted train step (grad accum + AdamW + clip + ABFT metrics),
  * checksummed async checkpoints + crash-restart resume,
  * a simulated mid-run crash: the loop is killed and restarted, resumes
    from the newest committed checkpoint and reaches the same final state.
"""
import argparse
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data import make_dataset
from repro.launch.steps import init_train_state, make_train_step
from repro.layers.common import Ctx
from repro.models.base import build_model
from repro.runtime import LoopConfig, TrainLoop

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params, 300 steps (minutes on CPU)")
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

if args.full:
    cfg = ArchConfig(name="lm100m", family="dense", n_layers=8,
                     d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                     vocab=32000, head_dim=64, attn_chunk=256)
    seq, batch, steps = 256, 8, args.steps or 300
else:
    cfg = ArchConfig(name="lm8m", family="dense", n_layers=4,
                     d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                     vocab=4096, head_dim=32, attn_chunk=64)
    seq, batch, steps = 128, 8, args.steps or 60

shape = ShapeConfig("ex", "train", seq, batch)
model = build_model(cfg, max_pos=seq + 8)
ctx = Ctx(quant=False, compute_dtype=jnp.bfloat16)
step_fn = jax.jit(make_train_step(model, ctx, accum=2, peak_lr=1e-3,
                                  warmup=20, total_steps=steps),
                  donate_argnums=(0,))

ckpt_dir = "/tmp/repro_example_ckpt"
shutil.rmtree(ckpt_dir, ignore_errors=True)
dataset = make_dataset(cfg, shape)
state = init_train_state(model, jax.random.key(0))
n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
      f"steps={steps}  seq={seq} batch={batch}")

losses = []


def hook(step, metrics):
    losses.append(float(metrics["loss_final"]))
    print(f"  step {step:4d}  loss {losses[-1]:.4f}  "
          f"lr {float(metrics['lr']):.2e}  "
          f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)


loop = TrainLoop(step_fn, dataset,
                 cfg=LoopConfig(ckpt_dir=ckpt_dir, save_every=20,
                                log_every=10, fault_policy="recompute"),
                 metrics_hook=hook)

# ---- phase 1: train to 60% of the run, then simulate a crash --------------
crash_at = int(steps * 0.6)
print(f"\n[phase 1] training to step {crash_at}, then 'crashing'...")
state_mid, _ = loop.run(state, crash_at, resume=False)
loop.ckpt.wait()
print(f"[crash] process gone. committed checkpoints: "
      f"{sorted(os.listdir(ckpt_dir))}")

# ---- phase 2: a NEW loop (fresh process in real life) resumes --------------
print("\n[phase 2] restart: resuming from latest committed checkpoint")
state_fresh = init_train_state(model, jax.random.key(0))
loop2 = TrainLoop(step_fn, dataset,
                  cfg=LoopConfig(ckpt_dir=ckpt_dir, save_every=20,
                                 log_every=10, fault_policy="recompute"),
                  metrics_hook=hook)
state_final, metrics = loop2.run(state_fresh, steps)

print(f"\nfinal loss {float(metrics['loss_final']):.4f} "
      f"(first logged {losses[0]:.4f}) — "
      f"{'improved' if losses[-1] < losses[0] else 'NOT improved'}")
print(f"loop stats: {loop2.stats}")
assert losses[-1] < losses[0], "training did not reduce loss"
print("train_fault_tolerant OK")
